//! Matrix-level numeric ops: GEMM (packed, register-tiled, threaded),
//! norms, dots.
//!
//! The GEMM is the classic pack-and-microkernel scheme: B is packed once
//! into NR-wide column strips, each worker packs MR-row strips of A
//! k-major, and an MR×NR register-resident microkernel streams the two
//! panels.  Workers write disjoint row ranges of the preallocated output
//! directly (no per-piece copy), and the per-(i,j) floating-point
//! addition order is a plain ascending-k sum — identical for every
//! worker count, so results stay bitwise worker-independent.

use super::matrix::{Matrix, Scalar};
use crate::error::{Error, Result};
use crate::util::threads;

/// Microkernel tile: MR rows × NR cols of C held in registers while the
/// packed K-panels stream through.  4×8 keeps the accumulator block
/// inside the vector-register budget for both f32 and f64 on 256-bit
/// SIMD hardware while letting LLVM autovectorize the inner loops.
const MR: usize = 4;
const NR: usize = 8;

/// Pack `b` (or `bᵀ` when `transposed`) into NR-wide column strips:
/// element (l, c) of strip t lands at `t·k·NR + l·NR + c`, zero-padded
/// to full strips so the microkernel never branches on the edge.
/// Returns (packed panels, strip count, logical column count n).
fn pack_b<T: Scalar>(b: &Matrix<T>, transposed: bool) -> (Vec<T>, usize, usize) {
    let (k, n) = if transposed { (b.cols, b.rows) } else { (b.rows, b.cols) };
    let tiles = n.div_ceil(NR).max(1);
    let mut packed = vec![T::ZERO; tiles * k * NR];
    for t in 0..tiles {
        let c0 = t * NR;
        let w = NR.min(n.saturating_sub(c0));
        let base = t * k * NR;
        if transposed {
            for c in 0..w {
                let brow = b.row(c0 + c);
                for (l, &v) in brow.iter().enumerate() {
                    packed[base + l * NR + c] = v;
                }
            }
        } else {
            for l in 0..k {
                let brow = &b.row(l)[c0..c0 + w];
                packed[base + l * NR..base + l * NR + w].copy_from_slice(brow);
            }
        }
    }
    (packed, tiles, n)
}

/// Compute `rows` (≤ MR) rows of C starting at global row `r0`, writing
/// into `out` (row-major, `n` wide, local row 0 = global row `r0`).
fn gemm_strip<T: Scalar>(
    a: &Matrix<T>,
    r0: usize,
    rows: usize,
    packed_b: &[T],
    tiles: usize,
    n: usize,
    out: &mut [T],
) {
    let k = a.cols;
    // pack the A strip k-major: (l, r) at l·MR + r; short strips zero-pad
    let mut pa = vec![T::ZERO; k * MR];
    for r in 0..rows {
        let arow = a.row(r0 + r);
        for (l, &v) in arow.iter().enumerate() {
            pa[l * MR + r] = v;
        }
    }
    for t in 0..tiles {
        let c0 = t * NR;
        if c0 >= n {
            break;
        }
        let w = NR.min(n - c0);
        let bstrip = &packed_b[t * k * NR..(t + 1) * k * NR];
        let mut acc = [[T::ZERO; NR]; MR];
        for l in 0..k {
            let av = &pa[l * MR..l * MR + MR];
            let bv = &bstrip[l * NR..l * NR + NR];
            for r in 0..MR {
                let ar = av[r];
                let accr = &mut acc[r];
                for c in 0..NR {
                    accr[c] += ar * bv[c];
                }
            }
        }
        for r in 0..rows {
            out[r * n + c0..r * n + c0 + w].copy_from_slice(&acc[r][..w]);
        }
    }
}

/// Shared packed-GEMM driver: C = A·B (or A·Bᵀ).  Threads split the row
/// dimension into MR-aligned chunks and write their slice of the
/// preallocated output in place.
fn gemm_packed<T: Scalar>(a: &Matrix<T>, b: &Matrix<T>, transposed: bool) -> Result<Matrix<T>> {
    let (m, k) = (a.rows, a.cols);
    let (packed_b, tiles, n) = pack_b(b, transposed);
    let mut data = vec![T::ZERO; m * n];
    if m == 0 || n == 0 || k == 0 {
        return Matrix::from_vec(m, n, data);
    }
    let workers = if m * n * k > 1 << 20 { threads::default_workers() } else { 1 };
    let strips = m.div_ceil(MR);
    let chunk_rows = strips.div_ceil(workers.max(1)).max(1) * MR;
    if workers <= 1 || m <= chunk_rows {
        let mut s0 = 0;
        while s0 < m {
            let rows = MR.min(m - s0);
            gemm_strip(a, s0, rows, &packed_b, tiles, n, &mut data[s0 * n..(s0 + rows) * n]);
            s0 += rows;
        }
    } else {
        std::thread::scope(|scope| {
            for (widx, chunk) in data.chunks_mut(chunk_rows * n).enumerate() {
                let pb = &packed_b;
                scope.spawn(move || {
                    let r_base = widx * chunk_rows;
                    let rows_here = chunk.len() / n;
                    let mut s0 = 0;
                    while s0 < rows_here {
                        let rows = MR.min(rows_here - s0);
                        gemm_strip(
                            a,
                            r_base + s0,
                            rows,
                            pb,
                            tiles,
                            n,
                            &mut chunk[s0 * n..(s0 + rows) * n],
                        );
                        s0 += rows;
                    }
                });
            }
        });
    }
    Matrix::from_vec(m, n, data)
}

/// Packed, multi-threaded GEMM: C = A·B — the host-side hot path for
/// weight reconstruction (W′ = A·B), the blocked-QR trailing updates,
/// and the fp64 reference computations.
pub fn matmul<T: Scalar>(a: &Matrix<T>, b: &Matrix<T>) -> Result<Matrix<T>> {
    if a.cols != b.rows {
        return Err(Error::shape(format!(
            "matmul: {}x{} @ {}x{}",
            a.rows, a.cols, b.rows, b.cols
        )));
    }
    gemm_packed(a, b, false)
}

/// C = A·Bᵀ without materializing Bᵀ (the transpose happens inside the
/// pack, so it shares the microkernel — and the bits — with [`matmul`]).
pub fn matmul_nt<T: Scalar>(a: &Matrix<T>, b: &Matrix<T>) -> Result<Matrix<T>> {
    if a.cols != b.cols {
        return Err(Error::shape(format!(
            "matmul_nt: {}x{} @ ({}x{})ᵀ",
            a.rows, a.cols, b.rows, b.cols
        )));
    }
    gemm_packed(a, b, true)
}

/// C = Aᵀ·A (the Gram matrix of columns — exactly what the baselines
/// form and COALA avoids; exposed so the failure can be studied).
pub fn gram_t<T: Scalar>(a: &Matrix<T>) -> Matrix<T> {
    let n = a.cols;
    let mut g = Matrix::zeros(n, n);
    for i in 0..a.rows {
        let r = a.row(i);
        for p in 0..n {
            let v = r[p];
            let grow = g.row_mut(p);
            for q in 0..n {
                grow[q] += v * r[q];
            }
        }
    }
    g
}

/// Frobenius norm.
pub fn fro<T: Scalar>(a: &Matrix<T>) -> f64 {
    a.data.iter().map(|x| x.to_f64() * x.to_f64()).sum::<f64>().sqrt()
}

/// Spectral norm via power iteration on AᵀA (good to ~1e-8 with 100 its).
pub fn spectral_norm<T: Scalar>(a: &Matrix<T>, iters: usize) -> f64 {
    let n = a.cols;
    if n == 0 || a.rows == 0 {
        return 0.0;
    }
    let mut v: Vec<f64> = (0..n).map(|i| 1.0 + (i as f64 * 0.7).sin()).collect();
    let mut norm = 0.0;
    for _ in 0..iters {
        // w = A v ; v' = Aᵀ w
        let mut w = vec![0.0f64; a.rows];
        for (i, wi) in w.iter_mut().enumerate() {
            let r = a.row(i);
            *wi = r.iter().zip(&v).map(|(x, y)| x.to_f64() * y).sum();
        }
        let mut v2 = vec![0.0f64; n];
        for i in 0..a.rows {
            let r = a.row(i);
            let wi = w[i];
            for (j, vj) in v2.iter_mut().enumerate() {
                *vj += r[j].to_f64() * wi;
            }
        }
        norm = v2.iter().map(|x| x * x).sum::<f64>().sqrt();
        if norm == 0.0 {
            return 0.0;
        }
        for x in v2.iter_mut() {
            *x /= norm;
        }
        v = v2;
    }
    norm.sqrt()
}

/// Relative reconstruction error ‖(W−W′)X‖_F / ‖WX‖_F — the Fig. 1 metric
/// (computed in the Scalar precision of the inputs).
pub fn context_rel_err<T: Scalar>(w: &Matrix<T>, wp: &Matrix<T>, x: &Matrix<T>) -> Result<f64> {
    let diff = w.sub(wp)?;
    let num = fro(&matmul(&diff, x)?);
    let den = fro(&matmul(w, x)?);
    Ok(if den == 0.0 { num } else { num / den })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Textbook ikj triple loop — the reference the packed kernel must
    /// reproduce (bitwise: both sum k in ascending order per (i, j)).
    fn matmul_naive<T: Scalar>(a: &Matrix<T>, b: &Matrix<T>) -> Matrix<T> {
        let (m, k, n) = (a.rows, a.cols, b.cols);
        let mut out = Matrix::zeros(m, n);
        for i in 0..m {
            for l in 0..k {
                let av = a.get(i, l);
                let brow = b.row(l);
                let orow = out.row_mut(i);
                for j in 0..n {
                    orow[j] += av * brow[j];
                }
            }
        }
        out
    }

    #[test]
    fn matmul_small() {
        let a: Matrix<f64> =
            Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        let b: Matrix<f64> =
            Matrix::from_vec(3, 2, vec![7.0, 8.0, 9.0, 10.0, 11.0, 12.0]).unwrap();
        let c = matmul(&a, &b).unwrap();
        assert_eq!(c.data, vec![58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn matmul_matches_naive_on_edge_shapes() {
        // shapes straddling the MR/NR tile boundaries, incl. degenerate
        for &(m, k, n, seed) in &[
            (1usize, 1usize, 1usize, 1u64),
            (3, 5, 7, 2),
            (4, 8, 8, 3),
            (5, 9, 17, 4),
            (33, 7, 9, 5),
            (8, 1, 23, 6),
            (2, 64, 3, 7),
        ] {
            let a: Matrix<f64> = Matrix::randn(m, k, seed);
            let b: Matrix<f64> = Matrix::randn(k, n, seed + 100);
            let c = matmul(&a, &b).unwrap();
            let want = matmul_naive(&a, &b);
            assert_eq!(c.data, want.data, "{m}x{k}x{n}: packed differs from naive");
        }
    }

    #[test]
    fn matmul_matches_nt() {
        let a: Matrix<f64> = Matrix::randn(17, 9, 1);
        let b: Matrix<f64> = Matrix::randn(13, 9, 2);
        let c1 = matmul(&a, &b.transpose()).unwrap();
        let c2 = matmul_nt(&a, &b).unwrap();
        for (x, y) in c1.data.iter().zip(&c2.data) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn matmul_threaded_matches_serial() {
        // large enough to cross the threading threshold
        let a: Matrix<f32> = Matrix::randn(128, 200, 3);
        let b: Matrix<f32> = Matrix::randn(200, 64, 4);
        let c = matmul(&a, &b).unwrap();
        // spot-check against direct dot products
        for &(i, j) in &[(0usize, 0usize), (64, 32), (127, 63)] {
            let want: f64 = (0..200).map(|l| a.get(i, l) as f64 * b.get(l, j) as f64).sum();
            assert!((c.get(i, j) as f64 - want).abs() < 1e-3);
        }
    }

    #[test]
    fn matmul_threaded_is_bitwise_deterministic() {
        // above the threading threshold: the row-chunked packed kernel
        // must reproduce the single-strip reference bit for bit
        let a: Matrix<f64> = Matrix::randn(130, 90, 8);
        let b: Matrix<f64> = Matrix::randn(90, 130, 9);
        let c = matmul(&a, &b).unwrap();
        let want = matmul_naive(&a, &b);
        assert_eq!(c.data, want.data);
    }

    #[test]
    fn gram_is_symmetric_psd_diag() {
        let a: Matrix<f64> = Matrix::randn(20, 8, 5);
        let g = gram_t(&a);
        for i in 0..8 {
            assert!(g.get(i, i) >= 0.0);
            for j in 0..8 {
                assert!((g.get(i, j) - g.get(j, i)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn spectral_close_to_fro_for_rank1() {
        let u: Matrix<f64> = Matrix::randn(12, 1, 6);
        let v: Matrix<f64> = Matrix::randn(1, 9, 7);
        let a = matmul(&u, &v).unwrap();
        // rank-1: ‖A‖₂ = ‖A‖_F
        assert!((spectral_norm(&a, 60) - fro(&a)).abs() < 1e-6);
    }

    #[test]
    fn shape_checked() {
        let a: Matrix<f64> = Matrix::zeros(2, 3);
        assert!(matmul(&a, &a).is_err());
        assert!(matmul_nt(&a, &Matrix::zeros(2, 4)).is_err());
    }
}
