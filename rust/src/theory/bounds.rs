//! Theorems 1 & 5: convergence bounds for the regularized solution.

use crate::error::Result;
use crate::linalg::jacobi_svd;
use crate::tensor::ops::{fro, matmul, spectral_norm};
use crate::tensor::Matrix;

/// Spectral-gap diagnostics of WX at rank r.
#[derive(Debug, Clone)]
pub struct GapInfo {
    pub sigma_r: f64,
    pub sigma_r1: f64,
    /// σ_r − σ_{r+1}
    pub gap: f64,
    /// σ_r² − σ_{r+1}²
    pub gap2: f64,
}

/// Compute the gap quantities of WX needed by both bounds.
pub fn gap_info(w: &Matrix<f64>, x: &Matrix<f64>, r: usize) -> Result<GapInfo> {
    let wx = matmul(w, x)?;
    let svd = jacobi_svd(&wx, 60)?;
    let s_r = svd.s.get(r - 1).copied().unwrap_or(0.0);
    let s_r1 = svd.s.get(r).copied().unwrap_or(0.0);
    Ok(GapInfo { sigma_r: s_r, sigma_r1: s_r1, gap: s_r - s_r1, gap2: s_r * s_r - s_r1 * s_r1 })
}

/// Theorem 1 (general case):
/// ‖W₀ − W_μ‖_F ≤ 2‖W‖₂²‖W‖_F / (σ_r² − σ_{r+1}²) · μ.
pub fn theorem1_bound(w: &Matrix<f64>, gap: &GapInfo, mu: f64) -> f64 {
    let w2 = spectral_norm(w, 200);
    2.0 * w2 * w2 * fro(w) / gap.gap2 * mu
}

/// Theorem 5 (full-row-rank X, sharper constant):
/// ‖W₀ − W_μ‖_F ≤ ‖W‖₂‖W‖_F / (σ_r(WX) − σ_{r+1}(WX)) · μ / σ_n(X).
pub fn theorem5_bound(w: &Matrix<f64>, x: &Matrix<f64>, gap: &GapInfo, mu: f64) -> Result<f64> {
    let svd_x = jacobi_svd(x, 60)?;
    let sigma_min = *svd_x.s.last().unwrap();
    Ok(spectral_norm(w, 200) * fro(w) / gap.gap * mu / sigma_min)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coala::{coala_from_x, coala_regularized};
    use crate::linalg::qr_r_square;

    fn measured_gap_err(w: &Matrix<f64>, x: &Matrix<f64>, r: usize, mu: f64) -> f64 {
        let w0 = coala_from_x(w, x, 60).unwrap().truncate(r).reconstruct().unwrap();
        let rf = qr_r_square(&x.transpose()).unwrap();
        let wmu = coala_regularized(w, &rf, mu, 60).unwrap().truncate(r).reconstruct().unwrap();
        fro(&w0.sub(&wmu).unwrap())
    }

    #[test]
    fn theorem1_holds_on_random_instances() {
        for seed in 0..5u64 {
            let w: Matrix<f64> = Matrix::randn(9, 7, seed * 2 + 1);
            let x: Matrix<f64> = Matrix::randn(7, 30, seed * 2 + 2);
            let r = 3;
            let gap = gap_info(&w, &x, r).unwrap();
            for mu in [1e-3, 1e-2] {
                let measured = measured_gap_err(&w, &x, r, mu);
                let bound = theorem1_bound(&w, &gap, mu);
                assert!(measured <= bound * (1.0 + 1e-6) + 1e-10, "seed {seed} mu {mu}: {measured} > {bound}");
            }
        }
    }

    #[test]
    fn theorem5_holds_and_is_sharper_for_small_sigma_ratio() {
        let w: Matrix<f64> = Matrix::randn(8, 6, 11);
        let x: Matrix<f64> = Matrix::randn(6, 40, 12);
        let r = 2;
        let gap = gap_info(&w, &x, r).unwrap();
        let mu = 1e-3;
        let measured = measured_gap_err(&w, &x, r, mu);
        let b5 = theorem5_bound(&w, &x, &gap, mu).unwrap();
        assert!(measured <= b5 * (1.0 + 1e-6) + 1e-10, "{measured} > {b5}");
    }

    #[test]
    fn bounds_scale_linearly_in_mu() {
        let w: Matrix<f64> = Matrix::randn(6, 5, 21);
        let x: Matrix<f64> = Matrix::randn(5, 25, 22);
        let gap = gap_info(&w, &x, 2).unwrap();
        let b1 = theorem1_bound(&w, &gap, 1e-3);
        let b2 = theorem1_bound(&w, &gap, 2e-3);
        assert!((b2 / b1 - 2.0).abs() < 1e-12);
    }
}
