//! Constructed examples G.1 and G.2 from the paper's appendix.

use crate::error::Result;
use crate::tensor::lowp::{gram_lowp, Precision};
use crate::tensor::ops::matmul;
use crate::tensor::Matrix;
use crate::util::prng::Rng;

/// Example G.1: the 2×2 matrix whose Gram formation loses σ ≈ √ε.
///
/// X = [[1, 1], [0, √ε]] with ε = ε_p/2 (ε_p = the target format's unit
/// roundoff).  XᵀX = [[1, 1], [1, 1+ε]]; forming it in precision p
/// rounds 1+ε back to 1, making the Gram exactly singular.  Returns
/// (σ_exact_min, σ_via_gram_min): the true smallest singular value of X
/// and the one recovered from the precision-p Gram matrix — the latter
/// collapses, demonstrating the O(√ε) loss.
pub fn example_g1(p: Precision) -> Result<(f64, f64)> {
    let eps = p.eps() / 2.0;
    let x = Matrix::<f32>::from_vec(2, 2, vec![1.0, 1.0, 0.0, (eps as f32).sqrt()])?;
    // exact singular values in f64
    let xf: Matrix<f64> = x.cast();
    let svd = crate::linalg::jacobi_svd(&xf, 60)?;
    let exact_min = *svd.s.last().unwrap();

    // Gram formed in precision p (rows of X are the "samples" so the
    // accumulation is XᵀX, the paper's matrix), spectrum in f64
    let g = gram_lowp(&x, p);
    let gf: Matrix<f64> = g.cast();
    let (lam, _) = crate::linalg::eigh(&gf, 60)?;
    let gram_min = lam.last().unwrap().max(0.0).sqrt();
    Ok((exact_min, gram_min))
}

/// One instance of Example G.2: a synthetic WX with every spectral
/// quantity pinned except the σ_r/σ_{r+1} gap.
#[derive(Debug, Clone)]
pub struct G2Instance {
    pub w: Matrix<f64>,
    pub x: Matrix<f64>,
    pub rank: usize,
    pub gap: f64,
}

/// Build the G.2 family: fixed singular vectors and spectrum except that
/// σ_{r+1} = σ_r − gap.  As gap → 0 the regularized solution's
/// sensitivity grows like 1/gap (Fig. 6).
///
/// Construction: X = I (so WX = W) and W = U·diag(σ)·Vᵀ with frozen
/// random orthogonal U, V (from QR of a seeded Gaussian).
pub fn example_g2(n: usize, rank: usize, gap: f64, seed: u64) -> Result<G2Instance> {
    assert!(rank + 1 <= n);
    let mut rng = Rng::new(seed);
    let gauss_u: Matrix<f64> =
        Matrix::from_fn(n, n, |_, _| rng.normal());
    let gauss_v: Matrix<f64> =
        Matrix::from_fn(n, n, |_, _| rng.normal());
    let u = orthogonalize(&gauss_u)?;
    let v = orthogonalize(&gauss_v)?;

    // spectrum: 10, 9, …; σ_rank pinned, σ_{rank+1} = σ_rank − gap,
    // the tail decays below it.
    let mut sigma = vec![0.0f64; n];
    for (i, s) in sigma.iter_mut().enumerate().take(rank) {
        *s = 10.0 - i as f64 * (4.0 / rank as f64);
    }
    let s_r = sigma[rank - 1];
    sigma[rank] = s_r - gap;
    for i in rank + 1..n {
        sigma[i] = (s_r - gap) * 0.5_f64.powi((i - rank) as i32);
    }

    let mut us = u.clone();
    for i in 0..n {
        for j in 0..n {
            us.set(i, j, u.get(i, j) * sigma[j]);
        }
    }
    let w = matmul(&us, &v.transpose())?;
    Ok(G2Instance { w, x: Matrix::eye(n), rank, gap })
}

/// Gram–Schmidt orthogonalization (QR's Q via MGS; only used to build
/// test fixtures, so numerical elegance is not critical).
fn orthogonalize(a: &Matrix<f64>) -> Result<Matrix<f64>> {
    let (m, n) = (a.rows, a.cols);
    let mut q = a.clone();
    for j in 0..n {
        for k in 0..j {
            let mut dot = 0.0;
            for i in 0..m {
                dot += q.get(i, k) * q.get(i, j);
            }
            for i in 0..m {
                let v = q.get(i, j) - dot * q.get(i, k);
                q.set(i, j, v);
            }
        }
        let norm: f64 = (0..m).map(|i| q.get(i, j).powi(2)).sum::<f64>().sqrt();
        for i in 0..m {
            q.set(i, j, q.get(i, j) / norm);
        }
    }
    Ok(q)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coala::{coala_from_x, coala_regularized};
    use crate::linalg::qr_r_square;
    use crate::tensor::ops::fro;

    #[test]
    fn g1_gram_loses_sqrt_eps() {
        let (exact, via_gram) = example_g1(Precision::F16).unwrap();
        // exact σ_min ≈ √(ε/2)/√2 > 0; fp16 Gram collapses it to ~0
        assert!(exact > 1e-3, "exact {exact}");
        assert!(via_gram < exact * 0.2, "gram path kept σ: {via_gram} vs {exact}");
    }

    #[test]
    fn g1_f32_also_loses() {
        let (exact, via_gram) = example_g1(Precision::F32).unwrap();
        assert!(exact > 1e-5);
        assert!(via_gram < exact * 0.2);
    }

    #[test]
    fn g2_spectrum_has_requested_gap() {
        let inst = example_g2(12, 4, 0.25, 7).unwrap();
        let svd = crate::linalg::jacobi_svd(&inst.w, 80).unwrap();
        assert!((svd.s[3] - svd.s[4] - 0.25).abs() < 1e-8);
    }

    #[test]
    fn g2_sensitivity_grows_as_gap_shrinks() {
        // ‖W₀ − W_μ‖ at fixed μ must grow when the gap shrinks
        let mu = 1e-3;
        let mut errs = Vec::new();
        for gap in [1.0, 0.1, 0.01] {
            let inst = example_g2(10, 3, gap, 3).unwrap();
            let w0 = coala_from_x(&inst.w, &inst.x, 80)
                .unwrap()
                .truncate(3)
                .reconstruct()
                .unwrap();
            let r = qr_r_square(&inst.x.transpose()).unwrap();
            let wmu = coala_regularized(&inst.w, &r, mu, 80)
                .unwrap()
                .truncate(3)
                .reconstruct()
                .unwrap();
            errs.push(fro(&w0.sub(&wmu).unwrap()));
        }
        assert!(errs[0] < errs[1] && errs[1] < errs[2], "{errs:?}");
    }
}
