//! Theory toolkit (S13): the paper's bounds and constructed examples.

pub mod bounds;
pub mod examples;

pub use bounds::{theorem1_bound, theorem5_bound, GapInfo};
pub use examples::{example_g1, example_g2, G2Instance};
