//! Criterion-style micro/macro benchmark harness (criterion itself is
//! not in the offline vendor set).  `cargo bench` targets use
//! `harness = false` and drive this directly.
//!
//! Methodology: warmup runs, then timed iterations until both a minimum
//! iteration count and a minimum wall budget are met; reports mean ±
//! sample std with min/max, matching how Table 1 reports `± std`.

use crate::error::Result;
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl Stats {
    pub fn from_samples(name: &str, samples: &[f64]) -> Stats {
        let n = samples.len().max(1) as f64;
        let mean = samples.iter().sum::<f64>() / n;
        let var = if samples.len() > 1 {
            samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / (n - 1.0)
        } else {
            0.0
        };
        Stats {
            name: name.to_string(),
            iters: samples.len(),
            mean_s: mean,
            std_s: var.sqrt(),
            min_s: samples.iter().cloned().fold(f64::INFINITY, f64::min),
            max_s: samples.iter().cloned().fold(0.0, f64::max),
        }
    }

    pub fn report(&self) -> String {
        format!(
            "{:<36} {:>10} ± {:<9} (n={}, min {}, max {})",
            self.name,
            fmt_dur(self.mean_s),
            fmt_dur(self.std_s),
            self.iters,
            fmt_dur(self.min_s),
            fmt_dur(self.max_s),
        )
    }
}

pub fn fmt_dur(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}µs", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

#[derive(Debug, Clone)]
pub struct BenchOpts {
    pub warmup: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub min_wall: Duration,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts {
            warmup: 2,
            min_iters: 5,
            max_iters: 50,
            min_wall: Duration::from_millis(500),
        }
    }
}

impl BenchOpts {
    /// Faster profile for expensive end-to-end benches (Table 1 scale).
    pub fn heavy() -> Self {
        BenchOpts { warmup: 1, min_iters: 3, max_iters: 5, min_wall: Duration::ZERO }
    }

    /// Honour `COALA_BENCH_FAST` (`1`/`true`/`yes`, case-insensitive)
    /// for CI-ish smoke runs.  Any other non-empty value is a hard
    /// error — `COALA_BENCH_FAST=fast` used to silently run the full
    /// sweep.
    pub fn from_env(self) -> Result<Self> {
        Ok(if crate::util::env::flag("COALA_BENCH_FAST")? {
            BenchOpts { warmup: 0, min_iters: 1, max_iters: 2, min_wall: Duration::ZERO }
        } else {
            self
        })
    }

    /// Pure core of [`BenchOpts::from_env`], testable without touching
    /// the process environment.
    pub fn from_flag_value(self, v: &str) -> Result<Self> {
        Ok(if crate::util::env::flag_value("COALA_BENCH_FAST", v)? {
            BenchOpts { warmup: 0, min_iters: 1, max_iters: 2, min_wall: Duration::ZERO }
        } else {
            self
        })
    }
}

/// Time `f`, which must consume its own inputs (use `std::hint::black_box`
/// inside to defeat DCE).  Returns per-iteration stats.
pub fn bench<F: FnMut()>(name: &str, opts: &BenchOpts, mut f: F) -> Stats {
    for _ in 0..opts.warmup {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < opts.min_iters
        || (start.elapsed() < opts.min_wall && samples.len() < opts.max_iters)
    {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
        if samples.len() >= opts.max_iters {
            break;
        }
    }
    let s = Stats::from_samples(name, &samples);
    println!("{}", s.report());
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_math() {
        let s = Stats::from_samples("x", &[1.0, 2.0, 3.0]);
        assert!((s.mean_s - 2.0).abs() < 1e-12);
        assert!((s.std_s - 1.0).abs() < 1e-12);
        assert_eq!(s.min_s, 1.0);
        assert_eq!(s.max_s, 3.0);
    }

    #[test]
    fn bench_runs() {
        let opts = BenchOpts { warmup: 1, min_iters: 3, max_iters: 4, min_wall: Duration::ZERO };
        let mut n = 0u64;
        let s = bench("noop", &opts, || {
            n = std::hint::black_box(n + 1);
        });
        assert!(s.iters >= 3);
    }

    #[test]
    fn fast_flag_grammar() {
        for on in ["1", "true", "YES"] {
            let o = BenchOpts::default().from_flag_value(on).unwrap();
            assert_eq!(o.max_iters, 2, "{on} must select the fast profile");
        }
        for off in ["", "0", "no", "False"] {
            let o = BenchOpts::default().from_flag_value(off).unwrap();
            assert_eq!(o.max_iters, BenchOpts::default().max_iters, "{off:?}");
        }
        for bad in ["2", "fast", "on"] {
            let e = BenchOpts::default().from_flag_value(bad).unwrap_err();
            assert!(e.to_string().contains("COALA_BENCH_FAST"), "{e}");
        }
    }

    #[test]
    fn fmt_scales() {
        assert!(fmt_dur(2.0).ends_with('s'));
        assert!(fmt_dur(2e-3).ends_with("ms"));
        assert!(fmt_dur(2e-6).ends_with("µs"));
        assert!(fmt_dur(2e-9).ends_with("ns"));
    }
}
