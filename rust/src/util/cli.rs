//! Tiny declarative CLI parser (replaces the unavailable `clap`).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and
//! positional arguments.  The `coala` binary defines subcommands on top.

use crate::calib::accumulate::AccumKind;
use crate::coala::compressor::Route;
use crate::coordinator::engine::{CheckpointCfg, EnginePlan};
use crate::error::{Error, Result};
use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse `argv[start..]`.  Flags with no following value (or followed
    /// by another flag) become boolean `"true"`.
    pub fn parse(argv: &[String]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.flags.insert(stripped.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{key} expects an integer, got `{v}`"))),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{key} expects a number, got `{v}`"))),
        }
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Comma-separated list flag.
    pub fn get_list(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.get(key) {
            Some(v) => v.split(',').map(str::to_string).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }

    /// `--seed N` → the environment seed (u64; shared by the repro
    /// harness and the `finetune` subcommand, so one flag spelling
    /// drives every synthetic generator).
    pub fn seed(&self, default: u64) -> Result<u64> {
        match self.get("seed") {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--seed expects an integer, got `{v}`"))),
        }
    }

    /// `--route device|host` → [`Route`] (default device).  Every repro
    /// driver and the compress/tsqr-demo subcommands share this flag:
    /// `host` selects pure-Rust accumulate/factorize and, in the repro
    /// harness, the synthetic artifact-free environment.
    pub fn route(&self) -> Result<Route> {
        match self.get_or("route", "device") {
            "device" => Ok(Route::Device),
            "host" => Ok(Route::Host),
            other => Err(Error::Config(format!(
                "--route is device or host, got `{other}`"
            ))),
        }
    }

    /// `--workers N` / `--queue-cap N` → the execution-engine plan every
    /// driver threads through (`coordinator::engine`).  `--workers`
    /// gives every stage N threads (default 1 = the sequential plan);
    /// `--queue-cap` bounds the in-flight capture batches (backpressure,
    /// default 2).  Results are identical at any worker count.
    pub fn engine_plan(&self) -> Result<EnginePlan> {
        let workers = self.get_usize("workers", 1)?;
        let queue_cap = self.get_usize("queue-cap", 2)?;
        let mut plan = EnginePlan::with_workers(workers);
        plan.queue_cap = queue_cap.max(1);
        Ok(plan)
    }

    /// `--checkpoint-dir DIR [--checkpoint-every N] [--resume]` →
    /// calibration checkpointing: pending merge states are written to
    /// DIR every N batches (default 4, atomically), and `--resume`
    /// continues a killed run from the last checkpoint.  Checkpointed
    /// and resumed runs produce bitwise the same factors as
    /// uninterrupted ones.  `None` when `--checkpoint-dir` is absent.
    pub fn checkpoint(&self) -> Result<Option<CheckpointCfg>> {
        let Some(dir) = self.get("checkpoint-dir") else {
            if self.get_bool("resume") {
                return Err(Error::Config("--resume needs --checkpoint-dir".into()));
            }
            return Ok(None);
        };
        Ok(Some(CheckpointCfg::new(
            dir,
            self.get_usize("checkpoint-every", 4)?,
            self.get_bool("resume"),
        )))
    }

    /// `--accum exact|sketch` → optional accumulator-kind override for
    /// the R-consuming methods (COALA, α-family).  `sketch` swaps the
    /// exact TSQR R for the seeded Gaussian range-finder sketch
    /// (`calib::accumulate::SketchAccumulator`): each batch folds in
    /// O(s·c·n) instead of O((n+c)·n²), at the HMT range-finder cost of
    /// an expected excess-residual factor √(1 + r/(p−1)) for
    /// oversampling p = s − r.  The sketch height s defaults to
    /// n/2 + 16 (clamped to n) and the Ω seed family to a fixed
    /// constant; `COALA_SKETCH_ROWS` / `COALA_SKETCH_SEED` override
    /// them, and both are folded into the run fingerprint so shards and
    /// checkpoints of one run can't silently disagree.  `exact` (or an
    /// absent flag) keeps the method's declared accumulator.
    pub fn accum(&self) -> Result<Option<AccumKind>> {
        match self.get("accum") {
            None | Some("exact") => Ok(None),
            Some("sketch") => Ok(Some(AccumKind::Sketch)),
            Some(other) => Err(Error::Config(format!(
                "--accum is exact or sketch, got `{other}`"
            ))),
        }
    }

    /// Assemble the method spec the `coala::compressor` registry resolves:
    /// `--method NAME` plus an optional `--lambda`/`--mu` parameter
    /// (spelled `NAME:lambda=V` / `NAME:mu=V`).  `--method coala:lambda=3`
    /// works too — an explicit parameter in the name wins.
    pub fn method_spec(&self, default: &str) -> String {
        let base = self.get_or("method", default);
        if base.contains(':') {
            return base.to_string();
        }
        if let Some(l) = self.get("lambda") {
            format!("{base}:lambda={l}")
        } else if let Some(m) = self.get("mu") {
            format!("{base}:mu={m}")
        } else {
            base.to_string()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse(&sv(&["compress", "--model", "tiny", "--ratio=0.7", "--verbose"]));
        assert_eq!(a.positional, vec!["compress"]);
        assert_eq!(a.get("model"), Some("tiny"));
        assert_eq!(a.get_f64("ratio", 0.0).unwrap(), 0.7);
        assert!(a.get_bool("verbose"));
        assert!(!a.get_bool("quiet"));
    }

    #[test]
    fn errors_on_bad_number() {
        let a = Args::parse(&sv(&["--n", "abc"]));
        assert!(a.get_usize("n", 1).is_err());
    }

    #[test]
    fn list_flag() {
        let a = Args::parse(&sv(&["--methods", "coala,svdllm"]));
        assert_eq!(a.get_list("methods", &["x"]), vec!["coala", "svdllm"]);
        assert_eq!(a.get_list("other", &["x"]), vec!["x"]);
    }

    #[test]
    fn seed_flag() {
        assert_eq!(Args::parse(&sv(&[])).seed(7).unwrap(), 7);
        assert_eq!(Args::parse(&sv(&["--seed", "123"])).seed(7).unwrap(), 123);
        // full u64 range (usize-based parsing used to be the only path)
        assert_eq!(
            Args::parse(&sv(&["--seed", "18446744073709551615"])).seed(0).unwrap(),
            u64::MAX
        );
        assert!(Args::parse(&sv(&["--seed", "x"])).seed(0).is_err());
    }

    #[test]
    fn route_flag() {
        assert_eq!(Args::parse(&sv(&[])).route().unwrap(), Route::Device);
        assert_eq!(
            Args::parse(&sv(&["--route", "host"])).route().unwrap(),
            Route::Host
        );
        assert!(Args::parse(&sv(&["--route", "tpu"])).route().is_err());
    }

    #[test]
    fn engine_plan_flags() {
        let p = Args::parse(&sv(&[])).engine_plan().unwrap();
        assert_eq!(
            (p.capture_workers, p.accum_shards, p.factorize_workers, p.queue_cap),
            (1, 1, 1, 2)
        );
        let p = Args::parse(&sv(&["--workers", "4", "--queue-cap", "8"]))
            .engine_plan()
            .unwrap();
        assert_eq!(
            (p.capture_workers, p.accum_shards, p.factorize_workers, p.queue_cap),
            (4, 4, 4, 8)
        );
        // zero never reaches the engine: everything clamps to ≥ 1
        let p = Args::parse(&sv(&["--workers", "0", "--queue-cap", "0"]))
            .engine_plan()
            .unwrap();
        assert_eq!(
            (p.capture_workers, p.accum_shards, p.factorize_workers, p.queue_cap),
            (1, 1, 1, 1)
        );
        assert!(Args::parse(&sv(&["--workers", "x"])).engine_plan().is_err());
    }

    #[test]
    fn checkpoint_flags() {
        assert!(Args::parse(&sv(&[])).checkpoint().unwrap().is_none());
        let c = Args::parse(&sv(&["--checkpoint-dir", "/tmp/ck", "--resume"]))
            .checkpoint()
            .unwrap()
            .unwrap();
        assert_eq!(c.dir, "/tmp/ck");
        assert_eq!(c.every, 4);
        assert!(c.resume);
        let c = Args::parse(&sv(&["--checkpoint-dir", "ck", "--checkpoint-every", "0"]))
            .checkpoint()
            .unwrap()
            .unwrap();
        assert_eq!(c.every, 1, "every clamps to ≥ 1");
        assert!(!c.resume);
        // --resume without a directory is a configuration error
        assert!(Args::parse(&sv(&["--resume"])).checkpoint().is_err());
    }

    #[test]
    fn accum_flag() {
        assert_eq!(Args::parse(&sv(&[])).accum().unwrap(), None);
        assert_eq!(Args::parse(&sv(&["--accum", "exact"])).accum().unwrap(), None);
        assert_eq!(
            Args::parse(&sv(&["--accum", "sketch"])).accum().unwrap(),
            Some(AccumKind::Sketch)
        );
        assert!(Args::parse(&sv(&["--accum", "gram"])).accum().is_err());
    }

    #[test]
    fn method_spec_assembly() {
        assert_eq!(Args::parse(&sv(&[])).method_spec("coala"), "coala");
        assert_eq!(
            Args::parse(&sv(&["--method", "svdllm"])).method_spec("coala"),
            "svdllm"
        );
        assert_eq!(
            Args::parse(&sv(&["--lambda", "3"])).method_spec("coala"),
            "coala:lambda=3"
        );
        assert_eq!(
            Args::parse(&sv(&["--mu", "0.1"])).method_spec("coala"),
            "coala:mu=0.1"
        );
        // explicit parameter in the name wins over stray flags
        assert_eq!(
            Args::parse(&sv(&["--method", "coala:mu=1", "--lambda", "3"])).method_spec("coala"),
            "coala:mu=1"
        );
    }
}
