//! `util::env` — strict parsing for `COALA_*` environment knobs.
//!
//! Every knob the crate reads goes through these helpers so that a knob
//! can never be *set but ignored*: unset means the default, a parsable
//! value is used, and anything else is a hard [`Error::Config`] naming
//! the variable and the offending value.  The pre-PR-7
//! `.ok().and_then(parse).unwrap_or(default)` pattern silently fell
//! back to the default on typos — fatal for knobs like
//! `COALA_SKETCH_ROWS` that every worker and shard of a run must agree
//! on (a typo'd shard would diverge from its siblings instead of
//! erroring).
//!
//! The `*_value` helpers are pure (no environment access) so unit tests
//! can cover the whole grammar without mutating process-global state:
//! the test harness runs tests concurrently in one process, and
//! `set_var` races with every other test that reads the environment.
//! End-to-end env-reading rejection tests live in
//! `rust/tests/env_knobs.rs`, serialized behind one mutex.
//!
//! The full knob table (every `COALA_*` variable, its grammar, and
//! which knobs are fingerprint-relevant) lives in the crate docs
//! (`lib.rs`, "Environment knobs").

use crate::error::{Error, Result};
use std::str::FromStr;

/// Read `name` from the environment and parse it as `T`.
///
/// Unset → `Ok(None)`.  Set but empty, non-UTF-8, or unparsable →
/// [`Error::Config`].
pub fn parse<T: FromStr>(name: &str) -> Result<Option<T>> {
    match read(name)? {
        None => Ok(None),
        Some(v) => parse_value(name, &v).map(Some),
    }
}

/// Read `name`, substituting `default` when unset.
pub fn parse_or<T: FromStr>(name: &str, default: T) -> Result<T> {
    Ok(parse(name)?.unwrap_or(default))
}

/// Parse an already-read knob value (pure — testable without touching
/// the process environment).
pub fn parse_value<T: FromStr>(name: &str, v: &str) -> Result<T> {
    let t = v.trim();
    if t.is_empty() {
        return Err(Error::Config(format!(
            "{name} is set but empty; unset it to use the default"
        )));
    }
    t.parse::<T>().map_err(|_| {
        Error::Config(format!(
            "{name}: cannot parse `{v}` as {}",
            std::any::type_name::<T>()
        ))
    })
}

/// Boolean knob: unset or empty → `false`; `1`/`true`/`yes`
/// (case-insensitive) → `true`; `0`/`false`/`no` → `false`; anything
/// else is a hard error.
pub fn flag(name: &str) -> Result<bool> {
    match read(name)? {
        None => Ok(false),
        Some(v) => flag_value(name, &v),
    }
}

/// Parse an already-read boolean knob value (pure).
pub fn flag_value(name: &str, v: &str) -> Result<bool> {
    match v.trim().to_ascii_lowercase().as_str() {
        "" => Ok(false),
        "1" | "true" | "yes" => Ok(true),
        "0" | "false" | "no" => Ok(false),
        _ => Err(Error::Config(format!(
            "{name}: expected 1/true/yes or 0/false/no, got `{v}`"
        ))),
    }
}

/// Boolean knob with an explicit default for knobs that are *on* unless
/// disabled (e.g. `COALA_SVD_QR_PRECOND`): unset → `default`, otherwise
/// the [`flag_value`] grammar (set-but-garbage is still a hard error).
pub fn flag_or(name: &str, default: bool) -> Result<bool> {
    match read(name)? {
        None => Ok(default),
        Some(v) => flag_value(name, &v),
    }
}

/// String knob (e.g. a path): unset → `None`; empty is rejected so a
/// dangling `COALA_X= cmd` cannot pass an empty path downstream.
pub fn string(name: &str) -> Result<Option<String>> {
    match read(name)? {
        None => Ok(None),
        Some(v) if v.trim().is_empty() => Err(Error::Config(format!(
            "{name} is set but empty; unset it to disable"
        ))),
        Some(v) => Ok(Some(v)),
    }
}

fn read(name: &str) -> Result<Option<String>> {
    match std::env::var_os(name) {
        None => Ok(None),
        Some(os) => os
            .into_string()
            .map(Some)
            .map_err(|_| Error::Config(format!("{name} is not valid UTF-8"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_value_accepts_plain_numbers() {
        assert_eq!(parse_value::<usize>("K", "42").unwrap(), 42);
        assert_eq!(parse_value::<u64>("K", " 7 ").unwrap(), 7);
        assert_eq!(parse_value::<f64>("K", "0.5").unwrap(), 0.5);
    }

    #[test]
    fn parse_value_rejects_garbage_and_empty() {
        for bad in ["abc", "", "  ", "1.5x", "0x10"] {
            let e = parse_value::<usize>("COALA_SKETCH_ROWS", bad).unwrap_err();
            assert!(
                e.to_string().contains("COALA_SKETCH_ROWS"),
                "error must name the knob: {e}"
            );
        }
    }

    #[test]
    fn flag_value_grammar() {
        for yes in ["1", "true", "TRUE", "Yes", "yEs"] {
            assert!(flag_value("F", yes).unwrap(), "{yes}");
        }
        for no in ["", "0", "false", "No", "FALSE"] {
            assert!(!flag_value("F", no).unwrap(), "{no:?}");
        }
        for bad in ["2", "on", "y", "enable", "fast"] {
            let e = flag_value("COALA_BENCH_FAST", bad).unwrap_err();
            assert!(e.to_string().contains("COALA_BENCH_FAST"), "{e}");
        }
    }

    #[test]
    fn flag_or_keeps_default_only_when_unset() {
        // Read-only env access: the variable is never set by any test.
        assert!(flag_or("COALA_TEST_SURELY_UNSET_8", true).unwrap());
        assert!(!flag_or("COALA_TEST_SURELY_UNSET_8", false).unwrap());
    }

    #[test]
    fn unset_knobs_fall_through_to_defaults() {
        // Read-only env access: the variable is never set by any test.
        assert_eq!(
            parse_or::<usize>("COALA_TEST_SURELY_UNSET_7", 9).unwrap(),
            9
        );
        assert!(parse::<u64>("COALA_TEST_SURELY_UNSET_7").unwrap().is_none());
        assert!(!flag("COALA_TEST_SURELY_UNSET_7").unwrap());
        assert!(string("COALA_TEST_SURELY_UNSET_7").unwrap().is_none());
    }
}
