//! Minimal JSON: enough for manifest.json + experiment result dumps.
//!
//! Replaces serde_json (not in the offline vendor set).  Supports the
//! full JSON grammar minus exotic number forms.  Non-negative integer
//! literals parse to [`Json::UInt`] so u64 counters (telemetry) survive
//! a round trip bit-exactly; everything else parses to f64.

use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects use BTreeMap for deterministic iteration.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    /// Exact unsigned integer.  `Num(f64)` silently corrupts values
    /// above 2^53; u64 counters round-trip through this variant instead.
    UInt(u64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser { b: src.as_bytes(), pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(Error::Json(format!("trailing data at byte {}", p.pos)));
        }
        Ok(v)
    }

    pub fn parse_file(path: &str) -> Result<Json> {
        let src = std::fs::read_to_string(path)?;
        Json::parse(&src)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Json(format!("missing key `{key}`")))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            Json::UInt(n) => Some(*n as f64),
            _ => None,
        }
    }

    /// Exact unsigned integer: `UInt` as-is, or a `Num` that is a
    /// non-negative whole number inside the f64-exact range.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::UInt(n) => Some(*n),
            Json::Num(n) if n.fract() == 0.0 && *n >= 0.0 && *n < 9.0e15 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::UInt(n) => Some(*n as usize),
            _ => self.as_f64().map(|f| f as usize),
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn str_arr(&self) -> Result<Vec<String>> {
        self.as_arr()
            .ok_or_else(|| Error::Json("expected array".into()))?
            .iter()
            .map(|v| {
                v.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| Error::Json("expected string".into()))
            })
            .collect()
    }

    pub fn usize_arr(&self) -> Result<Vec<usize>> {
        self.as_arr()
            .ok_or_else(|| Error::Json("expected array".into()))?
            .iter()
            .map(|v| {
                v.as_usize()
                    .ok_or_else(|| Error::Json("expected number".into()))
            })
            .collect()
    }

    // -- construction helpers ------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn from_f64s(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Serialize (compact).
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    // JSON has no inf/NaN; serialize as null (what
                    // serde_json does).  The stability tables genuinely
                    // produce infinities on collapsed Gram routes.
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::UInt(n) => {
                let _ = write!(out, "{n}");
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.pos < self.b.len() && (self.b[self.pos] as char).is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(Error::Json(format!(
                "expected `{}` at byte {} (found {:?})",
                c as char,
                self.pos,
                self.peek().map(|b| b as char)
            )))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => Err(Error::Json("unexpected end of input".into())),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(Error::Json(format!("bad literal at byte {}", self.pos)))
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos])
            .map_err(|e| Error::Json(e.to_string()))?;
        // Plain digit runs keep exact u64 precision; anything signed,
        // fractional, or exponential (and digit runs beyond u64) takes
        // the f64 path.
        if s.bytes().all(|b| b.is_ascii_digit()) {
            if let Ok(u) = s.parse::<u64>() {
                return Ok(Json::UInt(u));
            }
        }
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| Error::Json(format!("bad number `{s}`: {e}")))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(Error::Json("unterminated string".into())),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                .map_err(|e| Error::Json(e.to_string()))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|e| Error::Json(e.to_string()))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => {
                            return Err(Error::Json(format!("bad escape {other:?}")));
                        }
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|e| Error::Json(e.to_string()))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(Error::Json(format!("expected , or }} got {other:?}"))),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                other => return Err(Error::Json(format!("expected , or ] got {other:?}"))),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny", "d": null, "e": true}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.req("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.req("b").unwrap().req("c").unwrap().as_str(), Some("x\ny"));
        let re = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        let v = Json::from_f64s(&[1.0, f64::INFINITY, f64::NAN, -2.5]);
        let s = v.dump();
        assert_eq!(s, "[1,null,null,-2.5]");
        // and the dump still re-parses
        let re = Json::parse(&s).unwrap();
        assert_eq!(re.as_arr().unwrap()[1], Json::Null);
    }

    #[test]
    fn parses_unicode_escape() {
        let v = Json::parse(r#""Abc""#).unwrap();
        assert_eq!(v.as_str(), Some("Abc"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn u64_max_roundtrips_exactly() {
        let v = Json::UInt(u64::MAX);
        let s = v.dump();
        assert_eq!(s, "18446744073709551615");
        let re = Json::parse(&s).unwrap();
        assert_eq!(re.as_u64(), Some(u64::MAX));
        assert_eq!(re, v);
        // f64 would have rounded: nearby values collapse to one float
        assert_eq!(u64::MAX as f64, (u64::MAX - 1024) as f64);
        // beyond u64 the parser falls back to f64 rather than erroring
        let big = Json::parse("99999999999999999999999").unwrap();
        assert!(matches!(big, Json::Num(_)));
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"s": ["a","b"], "n": [1,2,3]}"#).unwrap();
        assert_eq!(v.req("s").unwrap().str_arr().unwrap(), vec!["a", "b"]);
        assert_eq!(v.req("n").unwrap().usize_arr().unwrap(), vec![1, 2, 3]);
    }
}
