//! Utility substrates.
//!
//! The build is fully offline and the vendor set only covers the `xla`
//! crate's dependency closure, so the usual ecosystem crates (serde,
//! clap, criterion, proptest, rand, rayon, tokio) are unavailable.  Each
//! gets a small, focused replacement here — documented as an explicit
//! substitution in DESIGN.md §S14:
//!
//! * [`json`]   — JSON parser/writer (manifest.json, experiment dumps)
//! * [`prng`]   — SplitMix64 + xoshiro256** (deterministic workloads)
//! * [`cli`]    — declarative flag parser for the `coala` binary
//! * [`bench`]  — criterion-style measurement harness (warmup, outlier
//!                trimming, mean ± std) used by `cargo bench` targets
//! * [`prop`]   — miniature property-testing driver (random cases with
//!                shrinking-by-halving) for coordinator invariants
//! * [`table`]  — fixed-width table rendering for the repro reports
//! * [`threads`]— scoped worker-pool helpers (std::thread based)
//! * [`env`]    — strict `COALA_*` knob parsing (set-but-malformed is a
//!                hard error, never a silent default)

pub mod bench;
pub mod cli;
pub mod env;
pub mod json;
pub mod prng;
pub mod prop;
pub mod table;
pub mod threads;
