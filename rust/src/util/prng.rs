//! Deterministic PRNG substrate (replaces the unavailable `rand` crate).
//!
//! SplitMix64 for seeding, xoshiro256** for the stream — the standard
//! pairing.  All workload generation in benches/examples goes through
//! this so every experiment is bit-reproducible from its seed.

/// xoshiro256** seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.uniform() * n as f64) as usize % n
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-12 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    pub fn normal_vec_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32).collect()
    }

    pub fn normal_vec_f64(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            v.swap(i, self.below(i + 1));
        }
    }

    /// Sample `k` distinct indices from [0, n).
    pub fn choose_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            let k = r.below(10);
            assert!(k < 10);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let v = r.normal_vec_f64(20_000);
        let mean = v.iter().sum::<f64>() / v.len() as f64;
        let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / v.len() as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn choose_distinct_is_distinct() {
        let mut r = Rng::new(9);
        let mut got = r.choose_distinct(20, 10);
        got.sort_unstable();
        got.dedup();
        assert_eq!(got.len(), 10);
    }
}
