//! Miniature property-testing driver (proptest is not in the vendor set).
//!
//! `check(seed-cases, gen, prop)` runs `prop` over generated cases; on
//! failure it re-runs a deterministic shrink schedule (halving every
//! integer knob the generator exposes) and reports the smallest failure.
//! Coordinator invariants (routing, batching, budget allocation) and the
//! host linalg are covered with this.

use crate::util::prng::Rng;

/// Outcome of a property check.
#[derive(Debug)]
pub enum PropResult<C> {
    Ok { cases: usize },
    Failed { minimal: C, message: String, shrinks: usize },
}

/// A shrinkable case: produce strictly "smaller" variants of itself.
pub trait Shrink: Sized + Clone + std::fmt::Debug {
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        let mut v = Vec::new();
        if *self > 0 {
            v.push(self / 2);
            v.push(self - 1);
        }
        v
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut v: Vec<Self> = self.0.shrink().into_iter().map(|a| (a, self.1.clone())).collect();
        v.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        v
    }
}

impl<A: Shrink, B: Shrink, C: Shrink> Shrink for (A, B, C) {
    fn shrink(&self) -> Vec<Self> {
        let mut v: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone(), self.2.clone()))
            .collect();
        v.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b, self.2.clone())));
        v.extend(self.2.shrink().into_iter().map(|c| (self.0.clone(), self.1.clone(), c)));
        v
    }
}

/// Run `prop` on `cases` generated inputs; shrink on first failure.
pub fn check<C, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P) -> PropResult<C>
where
    C: Shrink,
    G: FnMut(&mut Rng) -> C,
    P: FnMut(&C) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for i in 0..cases {
        let case = gen(&mut rng);
        if let Err(msg) = prop(&case) {
            // greedy shrink
            let mut best = case;
            let mut best_msg = msg;
            let mut shrinks = 0;
            'outer: loop {
                for cand in best.shrink() {
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        shrinks += 1;
                        if shrinks > 200 {
                            break 'outer;
                        }
                        continue 'outer;
                    }
                }
                break;
            }
            let _ = i;
            return PropResult::Failed { minimal: best, message: best_msg, shrinks };
        }
    }
    PropResult::Ok { cases }
}

/// Panic (with the minimal counterexample) unless the property held.
pub fn assert_prop<C, G, P>(name: &str, seed: u64, cases: usize, gen: G, prop: P)
where
    C: Shrink,
    G: FnMut(&mut Rng) -> C,
    P: FnMut(&C) -> Result<(), String>,
{
    match check(seed, cases, gen, prop) {
        PropResult::Ok { .. } => {}
        PropResult::Failed { minimal, message, shrinks } => {
            panic!("property `{name}` failed after {shrinks} shrinks on {minimal:?}: {message}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_valid_property() {
        assert_prop("add-commutes", 1, 200, |r| (r.below(100), r.below(100)), |(a, b)| {
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    fn shrinks_to_small_counterexample() {
        let res = check(
            2,
            500,
            |r| r.below(1000),
            |&n| if n < 10 { Ok(()) } else { Err(format!("{n} too big")) },
        );
        match res {
            PropResult::Failed { minimal, .. } => assert!(minimal >= 10 && minimal <= 20),
            _ => panic!("should fail"),
        }
    }
}
