//! Fixed-width table rendering for the `coala repro …` reports so the
//! regenerated tables visually mirror the paper's.

pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String]| {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i == 0 {
                    line.push_str(&format!("{:<w$}", c, w = widths[i]));
                } else {
                    line.push_str(&format!("  {:>w$}", c, w = widths[i]));
                }
            }
            line
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format `mean ± err` the way the paper's tables do.
pub fn pm(mean: f64, err: f64, decimals: usize) -> String {
    format!("{mean:.d$}±{err:.d$}", d = decimals)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["method", "val"]);
        t.row(vec!["coala".into(), pm(1.23456, 0.01, 2)]);
        t.row(vec!["svd-llm-longer".into(), "9.99±0.00".into()]);
        let s = t.render();
        assert!(s.contains("== T =="));
        assert!(s.contains("1.23±0.01"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["x".into()]);
    }
}
