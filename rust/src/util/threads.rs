//! Scoped worker-pool helpers (std::thread based; rayon/tokio are not in
//! the offline vendor set).  Used by the TSQR tree scheduler and the
//! host-linalg parallel matmul.

/// Run `f(i)` for i in 0..n across up to `workers` scoped threads and
/// collect results in order.  `f` must be Sync; per-item work should be
/// coarse enough to amortize thread spawn (we chunk internally).
pub fn parallel_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let next = std::sync::atomic::AtomicUsize::new(0);
    let slots: Vec<std::sync::Mutex<&mut Option<T>>> =
        out.iter_mut().map(std::sync::Mutex::new).collect();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| {
                IN_WORKER.with(|w| w.set(true));
                loop {
                    let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let v = f(i);
                    **slots[i].lock().unwrap() = Some(v);
                }
            });
        }
    });
    out.into_iter().map(|o| o.expect("worker failed to fill slot")).collect()
}

thread_local! {
    static IN_WORKER: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Whether the current thread is a [`parallel_map`] worker.  Nested
/// kernels (e.g. the blocked Jacobi SVD under the engine's factorize
/// fan) consult this to stay sequential instead of oversubscribing the
/// machine with a second level of threads.  Never affects results —
/// every parallel kernel in the crate is bitwise worker-count-
/// independent by construction — only where the threads go.
pub fn in_worker() -> bool {
    IN_WORKER.with(|w| w.get())
}

/// Number of workers to default to (respects `COALA_THREADS`).
///
/// Parsed strictly, once (the call sites are hot GEMM paths): a
/// malformed or zero `COALA_THREADS` panics with the config error at
/// first use instead of being silently ignored — the callers cannot
/// return `Result`, and a typo'd thread count must not quietly run on
/// the autodetected default.
pub fn default_workers() -> usize {
    static WORKERS: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *WORKERS.get_or_init(|| {
        match crate::util::env::parse::<usize>("COALA_THREADS") {
            Ok(Some(0)) => panic!("COALA_THREADS: must be ≥ 1, got `0`"),
            Ok(Some(n)) => n,
            Ok(None) => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            Err(e) => panic!("{e}"),
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let v = parallel_map(100, 8, |i| i * i);
        assert_eq!(v.len(), 100);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i * i);
        }
    }

    #[test]
    fn single_worker_fallback() {
        let v = parallel_map(5, 1, |i| i + 1);
        assert_eq!(v, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn zero_items() {
        let v: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(v.is_empty());
    }

    #[test]
    fn worker_threads_are_marked() {
        assert!(!in_worker(), "caller thread is not a worker");
        let marks = parallel_map(8, 4, |_| in_worker());
        assert!(marks.iter().all(|&m| m), "spawned workers must see the mark");
        // the sequential fallback runs on the caller thread, unmarked
        let marks = parallel_map(3, 1, |_| in_worker());
        assert!(marks.iter().all(|&m| !m));
        assert!(!in_worker(), "mark must not leak back to the caller");
    }
}
