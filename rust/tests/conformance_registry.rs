//! Cross-method conformance: every compressor in the registry must run
//! end-to-end on the host route — streaming accumulation through
//! `calib::accumulate`, factorization through the `Compressor` trait —
//! and match the fp64 reference factorization on well-conditioned data.
//! No artifacts, no PJRT: this is the suite that keeps the host fallback
//! path honest everywhere the device route exists.

use coala::calib::accumulate::{make_accumulator, AccumBackend, CalibAccumulator, CalibState};
use coala::coala::compressor::{registry, resolve, Compressor};
use coala::tensor::lowp::Precision;
use coala::tensor::ops::context_rel_err;
use coala::tensor::Matrix;

/// Stream X (n × k) through the host accumulator a compressor declares,
/// in `chunks` pieces — the same fold path the pipeline drives.
fn accumulate_host(
    comp: &dyn coala::coala::Compressor,
    x: &Matrix<f32>,
    chunks: usize,
) -> CalibState {
    let xt = x.transpose();
    let mut acc = make_accumulator(comp.accum_kind(), xt.cols, AccumBackend::Host, Precision::F32)
        .unwrap();
    let rows_per = xt.rows.div_ceil(chunks);
    let mut r0 = 0;
    while r0 < xt.rows {
        let r1 = (r0 + rows_per).min(xt.rows);
        acc.fold_chunk(&xt.slice(r0, r1, 0, xt.cols)).unwrap();
        r0 = r1;
    }
    acc.finish()
}

#[test]
fn every_registered_method_matches_fp64_reference() {
    let (m, n, k, rank) = (10usize, 8usize, 64usize, 3usize);
    let w32: Matrix<f32> = Matrix::randn(m, n, 11);
    let x32: Matrix<f32> = Matrix::randn(n, k, 12);
    let w64 = w32.cast::<f64>();
    let x64 = x32.cast::<f64>();

    for comp in registry() {
        // fp64 ground truth straight from raw X (Method::factorize_host)
        let ref64 = comp
            .method()
            .factorize_host(&w64, &x64, rank, 60)
            .unwrap_or_else(|e| panic!("{}: fp64 reference failed: {e}", comp.name()))
            .truncate(rank)
            .reconstruct()
            .unwrap();
        let err_ref = context_rel_err(&w64, &ref64, &x64).unwrap();

        // host route through the streaming accumulator + Compressor trait
        let calib = accumulate_host(comp.as_ref(), &x32, 4);
        let f = comp
            .factorize_host(&w32, &calib, rank, 60)
            .unwrap_or_else(|e| panic!("{}: host route failed: {e}", comp.name()));
        let rec = f.factors.truncate(rank).reconstruct().unwrap();
        let err_host = context_rel_err(&w32, &rec, &x32).unwrap();

        assert!(
            err_host.is_finite() && err_ref.is_finite(),
            "{}: non-finite errors ({err_host} vs {err_ref})",
            comp.name()
        );
        // f32 streaming accumulation vs fp64 direct: same optimum, small slack
        assert!(
            err_host <= err_ref + 2e-2,
            "{}: host route err {err_host} exceeds fp64 reference {err_ref}",
            comp.name()
        );
    }
}

#[test]
fn accumulator_kinds_cover_the_registry() {
    use coala::calib::accumulate::AccumKind;
    let regs = registry();
    // the three accumulation strategies (plus the null one) all appear
    for kind in [AccumKind::RFactor, AccumKind::Gram, AccumKind::Scales, AccumKind::None] {
        assert!(
            regs.iter().any(|c| c.accum_kind() == kind),
            "no registered method uses {kind:?}"
        );
    }
}

#[test]
fn gram_methods_report_near_singular_inputs() {
    // k < n: the Gram matrix is exactly singular.  Gram-consuming methods
    // must surface that as a Result (or finite factors) — never a panic,
    // never silent ±inf/NaN factors flowing downstream.
    let (m, n, k, rank) = (6usize, 9usize, 4usize, 2usize);
    let w: Matrix<f32> = Matrix::randn(m, n, 21);
    let x: Matrix<f32> = Matrix::randn(n, k, 22);

    for comp in registry() {
        let calib = accumulate_host(comp.as_ref(), &x, 2);
        match comp.factorize_host(&w, &calib, rank, 60) {
            Ok(f) => {
                let t = f.factors.truncate(rank);
                assert!(
                    t.a.all_finite() && t.b.all_finite(),
                    "{}: Ok result with non-finite factors on singular input",
                    comp.name()
                );
            }
            Err(e) => {
                // reported, not panicked — the acceptable failure mode
                let msg = e.to_string();
                assert!(!msg.is_empty(), "{}: empty error", comp.name());
            }
        }
    }
}

/// Context error of a compressor's host-route rank-`rank` reconstruction.
fn host_context_err(
    comp: &dyn Compressor,
    w: &Matrix<f32>,
    x: &Matrix<f32>,
    rank: usize,
) -> Result<f64, String> {
    let calib = accumulate_host(comp, x, 3);
    let f = comp.factorize_host(w, &calib, rank, 60).map_err(|e| e.to_string())?;
    let t = f.factors.truncate(rank);
    if !(t.a.all_finite() && t.b.all_finite()) {
        return Err(format!("{}: Ok with non-finite factors", comp.name()));
    }
    let rec = t.reconstruct().map_err(|e| e.to_string())?;
    context_rel_err(w, &rec, x).map_err(|e| e.to_string())
}

/// Near-singular + insufficient-data stress: every registered method on
/// (a) rank-deficient X via duplicated sample columns, (b) k < n
/// calibration.  Contract: never panic; never let NaN/Inf flow out of an
/// `Ok`; only the Gram route may refuse; and the inversion-free optimal
/// methods (COALA μ=0 ≡ α=1) must stay no worse than plain SVD on the
/// context error — the paper's stability guarantee (scenarios 2–3).
#[test]
fn near_singular_and_insufficient_data_stress() {
    use coala::calib::accumulate::AccumKind;
    let (m, n, rank) = (10usize, 8usize, 3usize);
    let w: Matrix<f32> = Matrix::randn(m, n, 41);

    // (a) duplicated sample columns: 24 samples, only 5 distinct → the
    // feature Gram XXᵀ is exactly singular (rank 5 < n = 8)
    let base: Matrix<f32> = Matrix::randn(n, 5, 42);
    let x_dup = Matrix::from_fn(n, 24, |i, j| base.get(i, j % 5));
    // (b) insufficient data: k = 4 < n = 8 samples
    let x_thin: Matrix<f32> = Matrix::randn(n, 4, 43);

    for (label, x) in [("duplicated-columns", &x_dup), ("k<n", &x_thin)] {
        let svd_err = host_context_err(resolve("svd").unwrap().as_ref(), &w, x, rank)
            .unwrap_or_else(|e| panic!("plain SVD must survive {label}: {e}"));
        assert!(svd_err.is_finite(), "plain SVD err on {label}");
        for comp in registry() {
            match host_context_err(comp.as_ref(), &w, x, rank) {
                Ok(err) => {
                    assert!(
                        err.is_finite(),
                        "{} on {label}: non-finite context error",
                        comp.name()
                    );
                }
                Err(msg) => {
                    // only the Gram route is allowed to collapse here,
                    // and it must do so with a reported error
                    assert_eq!(
                        comp.accum_kind(),
                        AccumKind::Gram,
                        "{} must survive {label}: {msg}",
                        comp.name()
                    );
                }
            }
        }
        // the paper-guaranteed orderings: the inversion-free optimal
        // methods match-or-beat context-free SVD on ‖(W−W′)X‖
        for spec in ["coala", "alpha1"] {
            let comp = resolve(spec).unwrap();
            let err = host_context_err(comp.as_ref(), &w, x, rank)
                .unwrap_or_else(|e| panic!("{spec} must survive {label}: {e}"));
            assert!(
                err <= svd_err + 5e-2,
                "{spec} on {label}: {err} worse than plain SVD {svd_err}"
            );
        }
    }
}

/// The same contract on the regime-controlled synthetic activation
/// generator the host-route drivers calibrate from.
#[test]
fn regime_chunks_stress_every_method() {
    use coala::calib::synthetic::{synth_chunk, Regime};

    let (m, n, rank) = (12usize, 16usize, 4usize);
    let w: Matrix<f32> = Matrix::randn(m, n, 51);
    for regime in [Regime::WellConditioned, Regime::NearSingular, Regime::Spiked] {
        for comp in registry() {
            let mut acc =
                make_accumulator(comp.accum_kind(), n, AccumBackend::Host, Precision::F32).unwrap();
            for b in 0..2u64 {
                acc.fold_chunk(&synth_chunk(40, n, regime, 60 + b)).unwrap();
            }
            let calib = acc.finish();
            match comp.factorize_host(&w, &calib, rank, 60) {
                Ok(f) => {
                    let t = f.factors.truncate(rank);
                    assert!(
                        t.a.all_finite() && t.b.all_finite(),
                        "{} on {regime:?}: Ok with non-finite factors",
                        comp.name()
                    );
                }
                Err(e) => {
                    assert!(
                        !e.to_string().is_empty(),
                        "{} on {regime:?}: empty error",
                        comp.name()
                    );
                }
            }
        }
    }
}

#[test]
fn spec_round_trips_every_registry_entry() {
    // every canonical instance's printed spec resolves back to itself —
    // what `coala methods` lists is exactly what `--method` accepts
    for comp in registry() {
        let again = resolve(&comp.spec())
            .unwrap_or_else(|e| panic!("{}: spec `{}` rejected: {e}", comp.name(), comp.spec()));
        assert_eq!(comp.method(), again.method(), "spec `{}` round-trip", comp.spec());
        assert_eq!(comp.accum_kind(), again.accum_kind());
    }
}
