//! Cross-method conformance: every compressor in the registry must run
//! end-to-end on the host route — streaming accumulation through
//! `calib::accumulate`, factorization through the `Compressor` trait —
//! and match the fp64 reference factorization on well-conditioned data.
//! No artifacts, no PJRT: this is the suite that keeps the host fallback
//! path honest everywhere the device route exists.

use coala::calib::accumulate::{make_accumulator, AccumBackend, CalibAccumulator, CalibState};
use coala::coala::compressor::{registry, resolve, Compressor};
use coala::tensor::lowp::Precision;
use coala::tensor::ops::context_rel_err;
use coala::tensor::Matrix;

/// Stream X (n × k) through the host accumulator a compressor declares,
/// in `chunks` pieces — the same fold path the pipeline drives.
fn accumulate_host(
    comp: &dyn coala::coala::Compressor,
    x: &Matrix<f32>,
    chunks: usize,
) -> CalibState {
    let xt = x.transpose();
    let mut acc =
        make_accumulator(comp.accum_kind(), xt.cols, AccumBackend::Host, Precision::F32);
    let rows_per = xt.rows.div_ceil(chunks);
    let mut r0 = 0;
    while r0 < xt.rows {
        let r1 = (r0 + rows_per).min(xt.rows);
        acc.fold_chunk(&xt.slice(r0, r1, 0, xt.cols)).unwrap();
        r0 = r1;
    }
    acc.finish()
}

#[test]
fn every_registered_method_matches_fp64_reference() {
    let (m, n, k, rank) = (10usize, 8usize, 64usize, 3usize);
    let w32: Matrix<f32> = Matrix::randn(m, n, 11);
    let x32: Matrix<f32> = Matrix::randn(n, k, 12);
    let w64 = w32.cast::<f64>();
    let x64 = x32.cast::<f64>();

    for comp in registry() {
        // fp64 ground truth straight from raw X (Method::factorize_host)
        let ref64 = comp
            .method()
            .factorize_host(&w64, &x64, rank, 60)
            .unwrap_or_else(|e| panic!("{}: fp64 reference failed: {e}", comp.name()))
            .truncate(rank)
            .reconstruct()
            .unwrap();
        let err_ref = context_rel_err(&w64, &ref64, &x64).unwrap();

        // host route through the streaming accumulator + Compressor trait
        let calib = accumulate_host(comp.as_ref(), &x32, 4);
        let f = comp
            .factorize_host(&w32, &calib, rank, 60)
            .unwrap_or_else(|e| panic!("{}: host route failed: {e}", comp.name()));
        let rec = f.factors.truncate(rank).reconstruct().unwrap();
        let err_host = context_rel_err(&w32, &rec, &x32).unwrap();

        assert!(
            err_host.is_finite() && err_ref.is_finite(),
            "{}: non-finite errors ({err_host} vs {err_ref})",
            comp.name()
        );
        // f32 streaming accumulation vs fp64 direct: same optimum, small slack
        assert!(
            err_host <= err_ref + 2e-2,
            "{}: host route err {err_host} exceeds fp64 reference {err_ref}",
            comp.name()
        );
    }
}

#[test]
fn accumulator_kinds_cover_the_registry() {
    use coala::calib::accumulate::AccumKind;
    let regs = registry();
    // the three accumulation strategies (plus the null one) all appear
    for kind in [AccumKind::RFactor, AccumKind::Gram, AccumKind::Scales, AccumKind::None] {
        assert!(
            regs.iter().any(|c| c.accum_kind() == kind),
            "no registered method uses {kind:?}"
        );
    }
}

#[test]
fn gram_methods_report_near_singular_inputs() {
    // k < n: the Gram matrix is exactly singular.  Gram-consuming methods
    // must surface that as a Result (or finite factors) — never a panic,
    // never silent ±inf/NaN factors flowing downstream.
    let (m, n, k, rank) = (6usize, 9usize, 4usize, 2usize);
    let w: Matrix<f32> = Matrix::randn(m, n, 21);
    let x: Matrix<f32> = Matrix::randn(n, k, 22);

    for comp in registry() {
        let calib = accumulate_host(comp.as_ref(), &x, 2);
        match comp.factorize_host(&w, &calib, rank, 60) {
            Ok(f) => {
                let t = f.factors.truncate(rank);
                assert!(
                    t.a.all_finite() && t.b.all_finite(),
                    "{}: Ok result with non-finite factors on singular input",
                    comp.name()
                );
            }
            Err(e) => {
                // reported, not panicked — the acceptable failure mode
                let msg = e.to_string();
                assert!(!msg.is_empty(), "{}: empty error", comp.name());
            }
        }
    }
}

#[test]
fn spec_round_trips_every_registry_entry() {
    // every canonical instance's printed spec resolves back to itself —
    // what `coala methods` lists is exactly what `--method` accepts
    for comp in registry() {
        let again = resolve(&comp.spec())
            .unwrap_or_else(|e| panic!("{}: spec `{}` rejected: {e}", comp.name(), comp.spec()));
        assert_eq!(comp.method(), again.method(), "spec `{}` round-trip", comp.spec());
        assert_eq!(comp.accum_kind(), again.accum_kind());
    }
}
