//! Determinism stress for the execution engine: calibration states and
//! compressed factors must be **bitwise identical** for every worker
//! count, across all four accumulator kinds (TSQR R / Gram / scales /
//! sketch), on synthetic data that includes the nearly singular regime (the
//! synthetic `tiny` model's layer 1 activations live in a low-rank
//! subspace with a 1e-2 noise floor — exactly where an order-dependent
//! floating-point reduction would leak the worker count into the bits).

use coala::calib::accumulate::{AccumBackend, AccumKind, CalibState, SketchKind};
use coala::calib::state::ShardState;
use coala::calib::synthetic::{regime_for_layer, Regime, SyntheticActivations};
use coala::coala::compressor::{resolve, Compressor, Route};
use coala::coordinator::pipeline::StageTimings;
use coala::coordinator::{
    engine, CalibStates, CheckpointCfg, CompressionJob, EnginePlan, Pipeline, ShardPlan,
};
use coala::model::synthetic::{synthetic_manifest, synthetic_weights};
use coala::runtime::Executor;
use coala::tensor::lowp::Precision;

fn assert_states_bitwise_eq(want: &CalibStates, got: &CalibStates, label: &str) {
    assert_eq!(want.len(), got.len(), "{label}: state count");
    for (k, sw) in want {
        match (sw, &got[k]) {
            (CalibState::R(a), CalibState::R(b)) => {
                assert_eq!(a.data, b.data, "{label} {k:?}: R bits differ")
            }
            (CalibState::Gram(a), CalibState::Gram(b)) => {
                assert_eq!(a.data, b.data, "{label} {k:?}: Gram bits differ")
            }
            (
                CalibState::Scales { sum_abs: a, rows: ra },
                CalibState::Scales { sum_abs: b, rows: rb },
            ) => {
                assert_eq!(a, b, "{label} {k:?}: scale sums differ");
                assert_eq!(ra, rb, "{label} {k:?}: row counts differ");
            }
            (
                CalibState::Sketch { y: a, folds: fa, kind: ka },
                CalibState::Sketch { y: b, folds: fb, kind: kb },
            ) => {
                assert_eq!(fa, fb, "{label} {k:?}: sketch fold counts differ");
                assert_eq!(ka, kb, "{label} {k:?}: sketch kinds differ");
                assert_eq!(a.data, b.data, "{label} {k:?}: sketch bits differ");
            }
            (CalibState::None, CalibState::None) => {}
            other => panic!("{label} {k:?}: state kind mismatch: {other:?}"),
        }
    }
}

/// Serializes every test that reads or writes the sketch env knobs —
/// sketch accumulators re-read `COALA_SKETCH_*` at construction, and
/// the test harness runs tests concurrently in one process.
static ENV_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn env_guard() -> std::sync::MutexGuard<'static, ()> {
    ENV_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

/// Run `f` with `var=value` set, restoring the prior value afterwards
/// (incl. on panic), under the env lock.
fn with_env<T>(var: &str, value: &str, f: impl FnOnce() -> T) -> T {
    let _g = env_guard();
    struct Restore(String, Option<std::ffi::OsString>);
    impl Drop for Restore {
        fn drop(&mut self) {
            match &self.1 {
                Some(v) => std::env::set_var(&self.0, v),
                None => std::env::remove_var(&self.0),
            }
        }
    }
    let _r = Restore(var.to_string(), std::env::var_os(var));
    std::env::set_var(var, value);
    f()
}

#[test]
fn engine_results_are_bitwise_identical_across_worker_counts() {
    let _env = env_guard(); // the sketch case reads COALA_SKETCH_*
    let ex = Executor::from_manifest(synthetic_manifest()).unwrap();
    let spec = ex.manifest.config("tiny").unwrap().clone();
    // the stress regime really is present: layer 1 is nearly singular
    assert_eq!(regime_for_layer(1), Regime::NearSingular);
    let w = synthetic_weights(&spec, 5);
    let src = SyntheticActivations::new(spec.clone(), 5);

    // one method per accumulator kind (R factor / Gram / scales), plus
    // the sketched range-finder riding coala's R-consuming route
    let cases = [
        ("coala", None),
        ("coala", Some(AccumKind::Sketch)),
        ("svdllm", None),
        ("asvd", None),
    ];
    for (method_spec, accum) in cases {
        let comp = resolve(method_spec).unwrap();
        let mut job = CompressionJob::new("tiny", comp.method(), 0.4);
        job.calib_batches = 3;

        let mut ref_states: Option<CalibStates> = None;
        let mut ref_factors: Option<Vec<(String, Vec<f32>, Vec<f32>)>> = None;
        for workers in [1usize, 2, 8] {
            let label = format!("{method_spec} accum={accum:?} workers={workers}");
            let pipe = Pipeline::new(&ex, spec.clone(), &w)
                .with_route(Route::Host)
                .with_accum(accum)
                .with_plan(EnginePlan::with_workers(workers));

            let mut t = StageTimings::default();
            let states = pipe.calibrate_from(&job, &src, &mut t).unwrap();
            let out = pipe.run_with_source(&job, &src).unwrap();
            assert!(out.model.all_finite(), "{label}");
            let factors: Vec<(String, Vec<f32>, Vec<f32>)> = out
                .model
                .factors
                .iter()
                .map(|(k, f)| (k.clone(), f.a.data.clone(), f.b.data.clone()))
                .collect();

            match (&ref_states, &ref_factors) {
                (None, None) => {
                    ref_states = Some(states);
                    ref_factors = Some(factors);
                }
                (Some(sw), Some(fw)) => {
                    assert_states_bitwise_eq(sw, &states, &label);
                    assert_eq!(fw, &factors, "{label}: compressed factors differ");
                }
                _ => unreachable!(),
            }
        }
    }
}

#[test]
fn shard_files_merged_out_of_process_match_the_engine_bitwise() {
    // The tentpole guarantee: N `coala shard` state files merged through
    // the codec must reproduce the single-process engine run **bitwise**
    // — states *and* factor files — for every accumulator kind, at every
    // shard count, including the nearly singular regime (layer 1).
    let _env = env_guard(); // the sketch case reads COALA_SKETCH_*
    let ex = Executor::from_manifest(synthetic_manifest()).unwrap();
    let spec = ex.manifest.config("tiny").unwrap().clone();
    assert_eq!(regime_for_layer(1), Regime::NearSingular);
    let w = synthetic_weights(&spec, 9);
    let src = SyntheticActivations::new(spec.clone(), 9);
    let total = 6;

    let cases = [
        ("coala", None),
        ("coala", Some(AccumKind::Sketch)),
        ("svdllm", None),
        ("asvd", None),
    ];
    for (method_spec, accum) in cases {
        let comp = resolve(method_spec).unwrap();
        let kind = accum.unwrap_or_else(|| comp.accum_kind());
        let mut job = CompressionJob::new("tiny", comp.method(), 0.4);
        job.calib_batches = total;
        let pipe = Pipeline::new(&ex, spec.clone(), &w)
            .with_route(Route::Host)
            .with_accum(accum);

        // single-process reference: engine states + factor file bytes
        let want = engine::calibrate(
            &src,
            kind,
            total,
            AccumBackend::Host,
            Precision::F32,
            &EnginePlan::sequential(),
            &mut StageTimings::default(),
        )
        .unwrap();
        let want_out = pipe.run_with_accums(&job, &want, StageTimings::default()).unwrap();
        let want_bytes = coala::calib::state::encode_factors(&want_out.model);

        for shards in [1usize, 2, 3, 5] {
            let plan = ShardPlan::new(total, shards).unwrap();
            // each shard accumulates independently (with its own worker
            // plan — shard-internal parallelism must not leak either),
            // then its state travels through the binary codec
            let parts: Vec<ShardState> = (0..shards)
                .map(|i| {
                    let st = engine::accumulate_shard(
                        &src,
                        kind,
                        plan.range(i).unwrap(),
                        AccumBackend::Host,
                        Precision::F32,
                        &EnginePlan::with_workers(1 + i % 3),
                        &mut StageTimings::default(),
                        None,
                        "tiny:host:seed9",
                    )
                    .unwrap();
                    ShardState::decode(&st.encode(), "<memory>").unwrap()
                })
                .collect();
            let got =
                engine::merge_shard_states(parts, AccumBackend::Host, &mut StageTimings::default())
                    .unwrap();
            let label = format!("{method_spec} accum={accum:?} shards={shards}");
            assert_states_bitwise_eq(&want, &got, &label);
            let got_out = pipe.run_with_accums(&job, &got, StageTimings::default()).unwrap();
            assert_eq!(
                want_bytes,
                coala::calib::state::encode_factors(&got_out.model),
                "{label}: factor files differ"
            );
        }
    }
}

#[test]
fn killed_checkpointed_pipeline_resumes_bitwise() {
    // checkpoint/resume at the pipeline level: a run killed mid-
    // calibration and resumed from its checkpoint produces factors
    // bitwise identical to the uninterrupted run
    use coala::calib::activations::{ActivationSource, CalibChunk};
    use coala::error::Error;

    struct DieAt<'a> {
        inner: &'a SyntheticActivations,
        from: usize,
    }
    impl ActivationSource for DieAt<'_> {
        fn capture_batch(&self, b: usize) -> coala::Result<Vec<CalibChunk>> {
            if b >= self.from {
                return Err(Error::msg(format!("simulated kill at batch {b}")));
            }
            self.inner.capture_batch(b)
        }
    }

    let ex = Executor::from_manifest(synthetic_manifest()).unwrap();
    let spec = ex.manifest.config("tiny").unwrap().clone();
    let w = synthetic_weights(&spec, 11);
    let src = SyntheticActivations::new(spec.clone(), 11);
    let comp = resolve("coala").unwrap();
    let mut job = CompressionJob::new("tiny", comp.method(), 0.4);
    job.calib_batches = 6;

    let want = Pipeline::new(&ex, spec.clone(), &w)
        .with_route(Route::Host)
        .run_with_source(&job, &src)
        .unwrap();

    let dir = std::env::temp_dir().join(format!("coala-resume-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let ckpt = CheckpointCfg::new(dir.display().to_string(), 2, true);
    // run 1: dies at batch 4, after the [0,2) and [2,4) checkpoints
    let killed = Pipeline::new(&ex, spec.clone(), &w)
        .with_route(Route::Host)
        .with_plan(EnginePlan::with_workers(2))
        .with_checkpoint(Some(ckpt.clone()))
        .run_with_source(&job, &DieAt { inner: &src, from: 4 });
    assert!(killed.is_err(), "the killed run must fail");
    // run 2: resumes from the checkpoint with the healthy source
    let got = Pipeline::new(&ex, spec.clone(), &w)
        .with_route(Route::Host)
        .with_plan(EnginePlan::with_workers(2))
        .with_checkpoint(Some(ckpt))
        .run_with_source(&job, &src)
        .unwrap();
    for (proj, f_want) in &want.model.factors {
        let f_got = &got.model.factors[proj];
        assert_eq!(f_want.a.data, f_got.a.data, "{proj}: A factor differs after resume");
        assert_eq!(f_want.b.data, f_got.b.data, "{proj}: B factor differs after resume");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn queue_capacity_does_not_change_results() {
    // backpressure (queue_cap = 1) vs a roomy queue: identical bits
    let ex = Executor::from_manifest(synthetic_manifest()).unwrap();
    let spec = ex.manifest.config("tiny").unwrap().clone();
    let w = synthetic_weights(&spec, 7);
    let src = SyntheticActivations::new(spec.clone(), 7);
    let comp = resolve("coala").unwrap();
    let mut job = CompressionJob::new("tiny", comp.method(), 0.5);
    job.calib_batches = 4;

    let mut reference: Option<CalibStates> = None;
    for queue_cap in [1usize, 8] {
        let mut plan = EnginePlan::with_workers(3);
        plan.queue_cap = queue_cap;
        let pipe = Pipeline::new(&ex, spec.clone(), &w)
            .with_route(Route::Host)
            .with_plan(plan);
        let mut t = StageTimings::default();
        let states = pipe.calibrate_from(&job, &src, &mut t).unwrap();
        match &reference {
            None => reference = Some(states),
            Some(want) => {
                assert_states_bitwise_eq(want, &states, &format!("queue_cap={queue_cap}"))
            }
        }
    }
}

#[test]
fn sketch_states_approximate_the_exact_gram_within_bound() {
    // the statistical contract of `--accum sketch`: R̂ from the sketch
    // is not the exact R, but its Gram form R̂ᵀR̂ = YᵀY/s must stay in
    // the range-finder ballpark of RᵀR = XᵀX.  At tiny's widths
    // (32 / 96) the default sketch height leaves little oversampling,
    // so the relative error is O(1); 2.0 is ~2× the worst case from a
    // 60-seed reference simulation of these shapes, while broken seed
    // plumbing or dropped batches land orders of magnitude away.
    let _env = env_guard();
    let spec = synthetic_manifest().config("tiny").unwrap().clone();
    let src = SyntheticActivations::new(spec.clone(), 13);
    let exact = calibrate_tiny(&src, AccumKind::RFactor);
    let sketch = calibrate_tiny(&src, AccumKind::Sketch);
    assert_sketch_tracks_exact(&exact, &sketch, SketchKind::Gaussian);
}

fn calibrate_tiny(src: &SyntheticActivations, kind: AccumKind) -> CalibStates {
    engine::calibrate(
        src,
        kind,
        4,
        AccumBackend::Host,
        Precision::F32,
        &EnginePlan::sequential(),
        &mut StageTimings::default(),
    )
    .unwrap()
}

fn assert_sketch_tracks_exact(exact: &CalibStates, sketch: &CalibStates, want_kind: SketchKind) {
    use coala::tensor::ops::{fro, matmul};
    assert_eq!(exact.len(), sketch.len());
    for (k, st) in sketch {
        let CalibState::Sketch { folds, kind, .. } = st else {
            panic!("{k:?}: expected a sketch state");
        };
        assert_eq!(*folds, 4, "{k:?}: sketch must count every batch");
        assert_eq!(*kind, want_kind, "{k:?}: wrong Ω family");
        let r_hat = st.r_factor().unwrap();
        let r = exact[k].r().unwrap();
        let got = matmul(&r_hat.transpose(), &r_hat).unwrap();
        let want = matmul(&r.transpose(), &r).unwrap();
        let err = fro(&got.sub(&want).unwrap()) / fro(&want).max(1e-12);
        assert!(err < 2.0, "{k:?}: relative sketch Gram error {err}");
        // the exact route must refuse to hand a sketch out as exact R
        assert!(st.r().is_err(), "{k:?}: r() must stay strict");
    }
}

#[test]
fn srht_states_approximate_the_exact_gram_within_bound() {
    // same statistical contract as the Gaussian family: sampled SHD
    // rows have ±1 entries, so E[ΩᵀΩ] = s·I and R̂ᵀR̂ = YᵀY/s tracks
    // XᵀX with the same O(1) tolerance at tiny's oversampling
    with_env("COALA_SKETCH_KIND", "srht", || {
        let spec = synthetic_manifest().config("tiny").unwrap().clone();
        let src = SyntheticActivations::new(spec.clone(), 13);
        let exact = calibrate_tiny(&src, AccumKind::RFactor);
        let sketch = calibrate_tiny(&src, AccumKind::Sketch);
        assert_sketch_tracks_exact(&exact, &sketch, SketchKind::Srht);
    });
}

#[test]
fn srht_engine_results_are_bitwise_identical_across_worker_counts() {
    // the fast-transform sketch inherits the leaf-indexed determinism:
    // states and factors must be bitwise worker-count-independent
    with_env("COALA_SKETCH_KIND", "srht", || {
        let ex = Executor::from_manifest(synthetic_manifest()).unwrap();
        let spec = ex.manifest.config("tiny").unwrap().clone();
        let w = synthetic_weights(&spec, 5);
        let src = SyntheticActivations::new(spec.clone(), 5);
        let comp = resolve("coala").unwrap();
        let mut job = CompressionJob::new("tiny", comp.method(), 0.4);
        job.calib_batches = 3;

        let mut ref_states: Option<CalibStates> = None;
        let mut ref_factors: Option<Vec<(String, Vec<f32>, Vec<f32>)>> = None;
        for workers in [1usize, 2, 8] {
            let label = format!("srht workers={workers}");
            let pipe = Pipeline::new(&ex, spec.clone(), &w)
                .with_route(Route::Host)
                .with_accum(Some(AccumKind::Sketch))
                .with_plan(EnginePlan::with_workers(workers));
            let mut t = StageTimings::default();
            let states = pipe.calibrate_from(&job, &src, &mut t).unwrap();
            for st in states.values() {
                let CalibState::Sketch { kind, .. } = st else { panic!("expected sketch") };
                assert_eq!(*kind, SketchKind::Srht, "{label}: knob did not reach the leaves");
            }
            let out = pipe.run_with_source(&job, &src).unwrap();
            assert!(out.model.all_finite(), "{label}");
            let factors: Vec<(String, Vec<f32>, Vec<f32>)> = out
                .model
                .factors
                .iter()
                .map(|(k, f)| (k.clone(), f.a.data.clone(), f.b.data.clone()))
                .collect();
            match (&ref_states, &ref_factors) {
                (None, None) => {
                    ref_states = Some(states);
                    ref_factors = Some(factors);
                }
                (Some(sw), Some(fw)) => {
                    assert_states_bitwise_eq(sw, &states, &label);
                    assert_eq!(fw, &factors, "{label}: compressed factors differ");
                }
                _ => unreachable!(),
            }
        }
    });
}

#[test]
fn srht_shard_merge_matches_single_process_bitwise() {
    // shard states travel through the codec (which now stamps the
    // sketch kind) and must merge back to the single-process bits
    with_env("COALA_SKETCH_KIND", "srht", || {
        let spec = synthetic_manifest().config("tiny").unwrap().clone();
        let src = SyntheticActivations::new(spec.clone(), 9);
        let total = 6;
        let want = engine::calibrate(
            &src,
            AccumKind::Sketch,
            total,
            AccumBackend::Host,
            Precision::F32,
            &EnginePlan::sequential(),
            &mut StageTimings::default(),
        )
        .unwrap();
        for shards in [2usize, 3] {
            let plan = ShardPlan::new(total, shards).unwrap();
            let parts: Vec<ShardState> = (0..shards)
                .map(|i| {
                    let st = engine::accumulate_shard(
                        &src,
                        AccumKind::Sketch,
                        plan.range(i).unwrap(),
                        AccumBackend::Host,
                        Precision::F32,
                        &EnginePlan::with_workers(1 + i % 3),
                        &mut StageTimings::default(),
                        None,
                        "tiny:host:seed9",
                    )
                    .unwrap();
                    ShardState::decode(&st.encode(), "<memory>").unwrap()
                })
                .collect();
            let got = engine::merge_shard_states(
                parts,
                AccumBackend::Host,
                &mut StageTimings::default(),
            )
            .unwrap();
            assert_states_bitwise_eq(&want, &got, &format!("srht shards={shards}"));
        }
    });
}
