//! Determinism stress for the execution engine: calibration states and
//! compressed factors must be **bitwise identical** for every worker
//! count, across all three accumulator kinds (TSQR R / Gram / scales),
//! on synthetic data that includes the nearly singular regime (the
//! synthetic `tiny` model's layer 1 activations live in a low-rank
//! subspace with a 1e-2 noise floor — exactly where an order-dependent
//! floating-point reduction would leak the worker count into the bits).

use coala::calib::accumulate::CalibState;
use coala::calib::synthetic::{regime_for_layer, Regime, SyntheticActivations};
use coala::coala::compressor::{resolve, Compressor, Route};
use coala::coordinator::pipeline::StageTimings;
use coala::coordinator::{CalibStates, CompressionJob, EnginePlan, Pipeline};
use coala::model::synthetic::{synthetic_manifest, synthetic_weights};
use coala::runtime::Executor;

fn assert_states_bitwise_eq(want: &CalibStates, got: &CalibStates, label: &str) {
    assert_eq!(want.len(), got.len(), "{label}: state count");
    for (k, sw) in want {
        match (sw, &got[k]) {
            (CalibState::R(a), CalibState::R(b)) => {
                assert_eq!(a.data, b.data, "{label} {k:?}: R bits differ")
            }
            (CalibState::Gram(a), CalibState::Gram(b)) => {
                assert_eq!(a.data, b.data, "{label} {k:?}: Gram bits differ")
            }
            (
                CalibState::Scales { sum_abs: a, rows: ra },
                CalibState::Scales { sum_abs: b, rows: rb },
            ) => {
                assert_eq!(a, b, "{label} {k:?}: scale sums differ");
                assert_eq!(ra, rb, "{label} {k:?}: row counts differ");
            }
            (CalibState::None, CalibState::None) => {}
            other => panic!("{label} {k:?}: state kind mismatch: {other:?}"),
        }
    }
}

#[test]
fn engine_results_are_bitwise_identical_across_worker_counts() {
    let ex = Executor::from_manifest(synthetic_manifest()).unwrap();
    let spec = ex.manifest.config("tiny").unwrap().clone();
    // the stress regime really is present: layer 1 is nearly singular
    assert_eq!(regime_for_layer(1), Regime::NearSingular);
    let w = synthetic_weights(&spec, 5);
    let src = SyntheticActivations::new(spec.clone(), 5);

    // one method per accumulator kind: R factor / Gram / scales
    for method_spec in ["coala", "svdllm", "asvd"] {
        let comp = resolve(method_spec).unwrap();
        let mut job = CompressionJob::new("tiny", comp.method(), 0.4);
        job.calib_batches = 3;

        let mut ref_states: Option<CalibStates> = None;
        let mut ref_factors: Option<Vec<(String, Vec<f32>, Vec<f32>)>> = None;
        for workers in [1usize, 2, 8] {
            let label = format!("{method_spec} workers={workers}");
            let pipe = Pipeline::new(&ex, spec.clone(), &w)
                .with_route(Route::Host)
                .with_plan(EnginePlan::with_workers(workers));

            let mut t = StageTimings::default();
            let states = pipe.calibrate_from(&job, &src, &mut t).unwrap();
            let out = pipe.run_with_source(&job, &src).unwrap();
            assert!(out.model.all_finite(), "{label}");
            let factors: Vec<(String, Vec<f32>, Vec<f32>)> = out
                .model
                .factors
                .iter()
                .map(|(k, f)| (k.clone(), f.a.data.clone(), f.b.data.clone()))
                .collect();

            match (&ref_states, &ref_factors) {
                (None, None) => {
                    ref_states = Some(states);
                    ref_factors = Some(factors);
                }
                (Some(sw), Some(fw)) => {
                    assert_states_bitwise_eq(sw, &states, &label);
                    assert_eq!(fw, &factors, "{label}: compressed factors differ");
                }
                _ => unreachable!(),
            }
        }
    }
}

#[test]
fn queue_capacity_does_not_change_results() {
    // backpressure (queue_cap = 1) vs a roomy queue: identical bits
    let ex = Executor::from_manifest(synthetic_manifest()).unwrap();
    let spec = ex.manifest.config("tiny").unwrap().clone();
    let w = synthetic_weights(&spec, 7);
    let src = SyntheticActivations::new(spec.clone(), 7);
    let comp = resolve("coala").unwrap();
    let mut job = CompressionJob::new("tiny", comp.method(), 0.5);
    job.calib_batches = 4;

    let mut reference: Option<CalibStates> = None;
    for queue_cap in [1usize, 8] {
        let mut plan = EnginePlan::with_workers(3);
        plan.queue_cap = queue_cap;
        let pipe = Pipeline::new(&ex, spec.clone(), &w)
            .with_route(Route::Host)
            .with_plan(plan);
        let mut t = StageTimings::default();
        let states = pipe.calibrate_from(&job, &src, &mut t).unwrap();
        match &reference {
            None => reference = Some(states),
            Some(want) => {
                assert_states_bitwise_eq(want, &states, &format!("queue_cap={queue_cap}"))
            }
        }
    }
}
