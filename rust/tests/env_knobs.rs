//! End-to-end rejection tests for the `COALA_*` environment knobs.
//!
//! PR 7's contract: a knob can never be *set but ignored*.  The pure
//! grammar is unit-tested inside `util::env` / `util::bench` /
//! `calib::accumulate` without touching the environment; these tests
//! cover the last step — the env-reading entry points themselves —
//! which requires `set_var`.  `set_var` is process-global and the test
//! harness runs tests concurrently in one process, so every test here
//! serializes behind one mutex and restores the variable before
//! releasing it.  No other test in *this binary* touches these
//! variables (each test binary is its own process; the determinism
//! suite has its own lock for `COALA_SKETCH_KIND`).

use coala::calib::accumulate::{make_accumulator, AccumBackend, AccumKind};
use coala::linalg::jacobi_svd;
use coala::tensor::lowp::Precision;
use coala::tensor::Matrix;
use coala::util::bench::BenchOpts;
use std::sync::Mutex;

static ENV_LOCK: Mutex<()> = Mutex::new(());

/// Run `f` with `var` set to `value` (`None` = unset), restoring the
/// previous state afterwards — even if `f` panics, via the guard.
fn with_env<T>(var: &str, value: Option<&str>, f: impl FnOnce() -> T) -> T {
    let _lock = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    struct Restore(String, Option<String>);
    impl Drop for Restore {
        fn drop(&mut self) {
            match &self.1 {
                Some(v) => std::env::set_var(&self.0, v),
                None => std::env::remove_var(&self.0),
            }
        }
    }
    let _restore = Restore(var.to_string(), std::env::var(var).ok());
    match value {
        Some(v) => std::env::set_var(var, v),
        None => std::env::remove_var(var),
    }
    f()
}

/// Two-variable variant of [`with_env`].  `ENV_LOCK` is not
/// reentrant, so nesting `with_env` calls deadlocks — knobs that are
/// only meaningful in combination (`COALA_MEM_BUDGET_MB` requires
/// `COALA_ALLOC_STATS`) take the lock once and restore both.
fn with_env2<T>(
    var1: &str,
    val1: Option<&str>,
    var2: &str,
    val2: Option<&str>,
    f: impl FnOnce() -> T,
) -> T {
    let _lock = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    struct Restore(String, Option<String>);
    impl Drop for Restore {
        fn drop(&mut self) {
            match &self.1 {
                Some(v) => std::env::set_var(&self.0, v),
                None => std::env::remove_var(&self.0),
            }
        }
    }
    let _r1 = Restore(var1.to_string(), std::env::var(var1).ok());
    let _r2 = Restore(var2.to_string(), std::env::var(var2).ok());
    for (var, val) in [(var1, val1), (var2, val2)] {
        match val {
            Some(v) => std::env::set_var(var, v),
            None => std::env::remove_var(var),
        }
    }
    f()
}

fn sketch_accum() -> coala::Result<Box<dyn coala::calib::accumulate::CalibAccumulator + 'static>> {
    make_accumulator(AccumKind::Sketch, 6, AccumBackend::Host, Precision::F32)
}

#[test]
fn sketch_rows_garbage_fails_at_construction() {
    for bad in ["abc", "1.5", "-3", ""] {
        let err = with_env("COALA_SKETCH_ROWS", Some(bad), || sketch_accum().unwrap_err());
        assert!(
            err.to_string().contains("COALA_SKETCH_ROWS"),
            "error must name the knob for {bad:?}: {err}"
        );
    }
}

#[test]
fn sketch_rows_zero_and_overwide_fail_at_construction() {
    let err = with_env("COALA_SKETCH_ROWS", Some("0"), || sketch_accum().unwrap_err());
    assert!(err.to_string().contains("must be ≥ 1"), "{err}");
    // width is 6 here; an explicit 4096-row sketch cannot be satisfied
    // and must error rather than silently clamp
    let err = with_env("COALA_SKETCH_ROWS", Some("4096"), || sketch_accum().unwrap_err());
    assert!(err.to_string().contains("out of range"), "{err}");
}

#[test]
fn sketch_rows_valid_value_is_used() {
    with_env("COALA_SKETCH_ROWS", Some("4"), || {
        sketch_accum().expect("explicit in-range COALA_SKETCH_ROWS must construct");
    });
}

#[test]
fn sketch_seed_garbage_fails_at_construction() {
    for bad in ["xyz", "0x10", " "] {
        let err = with_env("COALA_SKETCH_SEED", Some(bad), || sketch_accum().unwrap_err());
        assert!(
            err.to_string().contains("COALA_SKETCH_SEED"),
            "error must name the knob for {bad:?}: {err}"
        );
    }
}

/// A tall factorization small enough to be instant but large enough to
/// exercise both the QR preconditioner and the rotation schedule.
fn tiny_svd() -> coala::Result<coala::linalg::Svd<f64>> {
    jacobi_svd(&Matrix::<f64>::randn(9, 5, 3), 60)
}

#[test]
fn svd_par_cols_garbage_fails_at_the_call() {
    for bad in ["abc", "1.5", "-2", ""] {
        let err = with_env("COALA_SVD_PAR_COLS", Some(bad), || tiny_svd().unwrap_err());
        assert!(
            err.to_string().contains("COALA_SVD_PAR_COLS"),
            "error must name the knob for {bad:?}: {err}"
        );
    }
    let err = with_env("COALA_SVD_PAR_COLS", Some("0"), || tiny_svd().unwrap_err());
    assert!(err.to_string().contains("must be ≥ 1"), "{err}");
}

#[test]
fn svd_par_cols_engaging_the_fan_changes_no_bits() {
    // 5 columns ≥ threshold 2 ⇒ the parallel fan engages; the contract
    // says the result is bitwise identical to the sequential default
    let fanned = with_env("COALA_SVD_PAR_COLS", Some("2"), || tiny_svd().unwrap());
    let plain = with_env("COALA_SVD_PAR_COLS", None, || tiny_svd().unwrap());
    let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<u64>>();
    assert_eq!(bits(&fanned.s), bits(&plain.s), "σ bits");
    assert_eq!(bits(&fanned.u.data), bits(&plain.u.data), "U bits");
    assert_eq!(bits(&fanned.v.data), bits(&plain.v.data), "V bits");
}

#[test]
fn svd_qr_precond_garbage_fails_and_off_still_factors() {
    for bad in ["yep", "2", "enable"] {
        let err = with_env("COALA_SVD_QR_PRECOND", Some(bad), || tiny_svd().unwrap_err());
        assert!(err.to_string().contains("COALA_SVD_QR_PRECOND"), "{bad:?}: {err}");
    }
    // disabling the preconditioner is a legal A/B switch: same singular
    // values to fp tolerance, not necessarily the same bits
    let on = with_env("COALA_SVD_QR_PRECOND", None, || tiny_svd().unwrap());
    let off = with_env("COALA_SVD_QR_PRECOND", Some("0"), || tiny_svd().unwrap());
    let scale = 1.0 + on.s[0];
    for (a, b) in on.s.iter().zip(&off.s) {
        assert!((a - b).abs() <= 1e-9 * scale, "σ drift: {a} vs {b}");
    }
}

#[test]
fn sketch_kind_garbage_fails_at_construction() {
    for bad in ["gauss", "fast", "", "hadamard"] {
        let err = with_env("COALA_SKETCH_KIND", Some(bad), || sketch_accum().unwrap_err());
        assert!(
            err.to_string().contains("COALA_SKETCH_KIND"),
            "error must name the knob for {bad:?}: {err}"
        );
    }
}

#[test]
fn sketch_kind_valid_values_construct() {
    for ok in ["gaussian", "srht", "SRHT", " Gaussian "] {
        with_env("COALA_SKETCH_KIND", Some(ok), || {
            sketch_accum().unwrap_or_else(|e| panic!("{ok:?} must construct: {e}"));
        });
    }
}

#[test]
fn bench_fast_bad_values_are_loud() {
    for bad in ["2", "on", "enable", "fast"] {
        let err = with_env("COALA_BENCH_FAST", Some(bad), || {
            BenchOpts::default().from_env().unwrap_err()
        });
        assert!(err.to_string().contains("COALA_BENCH_FAST"), "{bad:?}: {err}");
    }
}

#[test]
fn bench_fast_grammar_is_case_insensitive() {
    for yes in ["1", "true", "TRUE", "Yes"] {
        let o = with_env("COALA_BENCH_FAST", Some(yes), || BenchOpts::heavy().from_env().unwrap());
        assert!(o.max_iters < BenchOpts::heavy().max_iters, "{yes} must shrink the budget");
    }
    for no in ["0", "false", "No"] {
        let o = with_env("COALA_BENCH_FAST", Some(no), || BenchOpts::heavy().from_env().unwrap());
        assert_eq!(o.max_iters, BenchOpts::heavy().max_iters, "{no} must keep the budget");
    }
}

#[test]
fn golden_regen_flag_rejects_garbage() {
    let err =
        with_env("COALA_GOLDEN_REGEN", Some("yep"), || {
            coala::util::env::flag("COALA_GOLDEN_REGEN").unwrap_err()
        });
    assert!(err.to_string().contains("COALA_GOLDEN_REGEN"), "{err}");
}

#[test]
fn telemetry_set_but_empty_is_an_error() {
    // On a telemetry build an empty path is rejected by the strict
    // string parser; on the default build *any* set value is rejected
    // because the knob cannot take effect.  Either way: loud.
    let err = with_env("COALA_TELEMETRY", Some(""), || {
        coala::telemetry::TelemetrySink::from_env().unwrap_err()
    });
    assert!(err.to_string().contains("COALA_TELEMETRY"), "{err}");
}

#[test]
fn health_flag_rejects_garbage_on_every_build() {
    // Garbage is a hard error on *both* builds: the telemetry build's
    // strict flag grammar rejects it, and the default build rejects the
    // knob being set at all.
    for bad in ["2", "on", "armed", " "] {
        let err = with_env("COALA_HEALTH", Some(bad), || {
            coala::telemetry::health::init_from_env().unwrap_err()
        });
        assert!(
            err.to_string().contains("COALA_HEALTH"),
            "error must name the knob for {bad:?}: {err}"
        );
    }
}

#[test]
fn health_flag_valid_value_arms_or_errs_by_build() {
    let res = with_env("COALA_HEALTH", Some("1"), coala::telemetry::health::init_from_env);
    if cfg!(feature = "telemetry") {
        assert!(res.unwrap(), "COALA_HEALTH=1 must arm the probes");
        assert!(coala::telemetry::health::enabled());
        coala::telemetry::health::set_enabled(false);
    } else {
        // a set-but-inert knob is a loud error, never silently ignored
        let err = res.unwrap_err();
        assert!(err.to_string().contains("COALA_HEALTH"), "{err}");
        assert!(err.to_string().contains("telemetry"), "must point at the missing feature: {err}");
    }
    // unset is plain off on every build
    let on = with_env("COALA_HEALTH", None, || {
        coala::telemetry::health::init_from_env().unwrap()
    });
    assert!(!on);
    assert!(!coala::telemetry::health::enabled());
}

#[test]
fn alloc_stats_flag_rejects_garbage_on_every_build() {
    // Same contract as COALA_HEALTH: strict flag grammar on the
    // telemetry build, set-at-all is an error on the default build.
    for bad in ["2", "on", "armed", " "] {
        let err = with_env("COALA_ALLOC_STATS", Some(bad), || {
            coala::telemetry::alloc::init_from_env().unwrap_err()
        });
        assert!(
            err.to_string().contains("COALA_ALLOC_STATS"),
            "error must name the knob for {bad:?}: {err}"
        );
    }
}

#[test]
fn alloc_stats_valid_value_arms_or_errs_by_build() {
    // The allocator gate is process-global and other tests in this
    // binary briefly arm it, so observe-and-disarm stays inside the
    // locked closure.
    let (res, was_armed) = with_env("COALA_ALLOC_STATS", Some("1"), || {
        let res = coala::telemetry::alloc::init_from_env();
        let was_armed = coala::telemetry::alloc::armed();
        coala::telemetry::alloc::set_armed(false);
        (res, was_armed)
    });
    if cfg!(feature = "telemetry") {
        assert!(res.unwrap(), "COALA_ALLOC_STATS=1 must arm the counters");
        assert!(was_armed);
    } else {
        let err = res.unwrap_err();
        assert!(err.to_string().contains("COALA_ALLOC_STATS"), "{err}");
        assert!(err.to_string().contains("telemetry"), "must point at the missing feature: {err}");
    }
    // unset is plain off on every build
    let (on, was_armed) = with_env("COALA_ALLOC_STATS", None, || {
        (coala::telemetry::alloc::init_from_env().unwrap(), coala::telemetry::alloc::armed())
    });
    assert!(!on);
    assert!(!was_armed);
}

#[test]
fn mem_budget_strict_grammar_is_loud() {
    // Garbage, fractional, negative, empty, and zero are all hard
    // errors.  On the default build the error blames COALA_ALLOC_STATS
    // (the first inert-but-set knob found) — loud either way.
    for bad in ["abc", "1.5", "-3", "", "0"] {
        let (err, was_armed) =
            with_env2("COALA_ALLOC_STATS", Some("1"), "COALA_MEM_BUDGET_MB", Some(bad), || {
                let err = coala::telemetry::alloc::init_from_env().unwrap_err();
                (err, coala::telemetry::alloc::armed())
            });
        let knob =
            if cfg!(feature = "telemetry") { "COALA_MEM_BUDGET_MB" } else { "COALA_ALLOC_STATS" };
        assert!(err.to_string().contains(knob), "error must name {knob} for {bad:?}: {err}");
        assert!(!was_armed, "a rejected config must not arm the counters ({bad:?})");
    }
}

#[test]
fn mem_budget_without_alloc_stats_is_a_hard_error() {
    // A budget with no stage peaks to compare against can never take
    // effect; the feature build demands COALA_ALLOC_STATS=1 alongside,
    // the default build rejects the set knob outright.
    let err = with_env2("COALA_ALLOC_STATS", None, "COALA_MEM_BUDGET_MB", Some("512"), || {
        coala::telemetry::alloc::init_from_env().unwrap_err()
    });
    assert!(err.to_string().contains("COALA_MEM_BUDGET_MB"), "{err}");
    if cfg!(feature = "telemetry") {
        assert!(
            err.to_string().contains("COALA_ALLOC_STATS"),
            "must point at the missing arm flag: {err}"
        );
    }
}

#[test]
fn mem_budget_valid_value_arms_by_build() {
    let (res, was_armed, budget) =
        with_env2("COALA_ALLOC_STATS", Some("1"), "COALA_MEM_BUDGET_MB", Some("512"), || {
            let res = coala::telemetry::alloc::init_from_env();
            let state = (coala::telemetry::alloc::armed(), coala::telemetry::alloc::budget_bytes());
            coala::telemetry::alloc::set_armed(false);
            coala::telemetry::alloc::set_budget(None);
            (res, state.0, state.1)
        });
    if cfg!(feature = "telemetry") {
        assert!(res.unwrap(), "valid pair must arm the counters");
        assert!(was_armed);
        assert_eq!(budget, Some(512 << 20), "512 MB budget in bytes");
    } else {
        let err = res.unwrap_err();
        assert!(err.to_string().contains("telemetry"), "must point at the missing feature: {err}");
    }
}

#[test]
fn artifacts_dir_set_but_empty_is_an_error() {
    let err = with_env("COALA_ARTIFACTS", Some("  "), || {
        coala::artifacts_dir(None).unwrap_err()
    });
    assert!(err.to_string().contains("COALA_ARTIFACTS"), "{err}");
    let dir = with_env("COALA_ARTIFACTS", Some("/tmp/x"), || coala::artifacts_dir(None).unwrap());
    assert_eq!(dir, "/tmp/x");
    // the CLI flag always wins without consulting the environment
    let dir = with_env("COALA_ARTIFACTS", Some("  "), || {
        coala::artifacts_dir(Some("flagged")).unwrap()
    });
    assert_eq!(dir, "flagged");
}
