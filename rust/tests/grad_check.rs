//! Finite-difference verification of the host training subsystem's
//! backward pass (`finetune::grad::GradModel`).
//!
//! Every adapter parameter group — (A, B) for all 6 projection families
//! of every layer — is checked against fp64 central differences:
//!
//! ```text
//!   ∂L/∂θ ≈ (L(θ + h) − L(θ − h)) / 2h
//! ```
//!
//! The whole check runs at fp64 (model, loss, perturbation), which is
//! the only precision where central differences resolve the gradient
//! above rounding noise.  Three regimes are covered:
//!
//! 1. a spectrally-initialized model (PiSSA) at its natural scale;
//! 2. adapters built from the *near-singular* calibration regime (the
//!    tiny config's layer 1 activations are rank-deficient by
//!    construction) and then amplified until gates and SiLUs saturate —
//!    the backward must stay exact where the forward is stiff;
//! 3. CorDA's Gram-inverting init, whose factors carry extreme values
//!    in the low-data regime (checked only when the inversion stays
//!    finite — a collapse is the Table 4 failure mode, not a gradient
//!    bug).
//!
//! A cross-precision consistency test pins the fp64 forward to the f32
//! host evaluator, so the gradients verified here are gradients of the
//! loss the tables actually report.

use coala::calib::dataset::Corpus;
use coala::calib::synthetic::SyntheticActivations;
use coala::finetune::{init_adapters_from_source, AdapterInit, AdapterSet, GradModel};
use coala::model::synthetic::{synthetic_manifest, synthetic_weights};
use coala::runtime::manifest::ModelSpec;
use coala::util::prng::Rng;

const SEED: u64 = 11;

fn world(strategy: AdapterInit) -> Option<(ModelSpec, AdapterSet)> {
    let m = synthetic_manifest();
    let spec = m.config("tiny").unwrap().clone();
    let w = synthetic_weights(&spec, SEED);
    // calibration from the regime-controlled source: layer 1's
    // activations are NearSingular by construction, so context-aware
    // inits inherit the near-singular regime
    let src = SyntheticActivations::new(spec.clone(), SEED);
    let set = init_adapters_from_source(&spec, &w, &src, strategy, 4, 2, 30).ok()?;
    set.all_finite().then_some((spec, set))
}

fn pairs(n: usize) -> Vec<(usize, usize)> {
    let corpus = Corpus::synthetic(64, 1024, SEED);
    let toks = corpus.split("ft_train").unwrap();
    toks.windows(2).take(n).map(|w| (w[0] as usize, w[1] as usize)).collect()
}

/// Check `samples` entries of every (A, B) group of `model` against
/// central differences.  Perturbation scale follows the entry magnitude
/// so both O(1) and near-zero parameters are probed sensibly.
fn check_all_groups(model: &mut GradModel, ps: &[(usize, usize)], tag: &str) {
    let (_, grads) = model.loss_and_grads(ps, 2).unwrap();
    let names: Vec<String> = model.proj_names().to_vec();
    let mut rng = Rng::new(0xC8EC);
    let samples = 4;
    for (pi, proj) in names.iter().enumerate() {
        for which in 0..2 {
            let g = if which == 0 { &grads[pi].0 } else { &grads[pi].1 };
            let (rows, cols) = (g.rows, g.cols);
            let picked = rng.choose_distinct(rows * cols, samples.min(rows * cols));
            for flat in picked {
                let (i, j) = (flat / cols, flat % cols);
                let ana = g.get(i, j);
                let probe = |m: &mut GradModel, v: f64| {
                    let (a, b) = m.adapter_mut(proj).unwrap();
                    if which == 0 {
                        a.set(i, j, v);
                    } else {
                        b.set(i, j, v);
                    }
                };
                let x0 = {
                    let (a, b) = model.adapter_mut(proj).unwrap();
                    if which == 0 { a.get(i, j) } else { b.get(i, j) }
                };
                let h = 1e-5 * x0.abs().max(1.0);
                probe(model, x0 + h);
                let lp = model.loss(ps).unwrap();
                probe(model, x0 - h);
                let lm = model.loss(ps).unwrap();
                probe(model, x0); // restore exactly
                let num = (lp - lm) / (2.0 * h);
                let tol = 5e-7 + 3e-5 * ana.abs().max(num.abs());
                assert!(
                    (ana - num).abs() <= tol,
                    "{tag}: {proj} {}[{i},{j}]: analytic {ana:e} vs central-diff {num:e} \
                     (|Δ| = {:e} > tol {tol:e})",
                    if which == 0 { "A" } else { "B" },
                    (ana - num).abs()
                );
            }
        }
    }
}

#[test]
fn gradients_match_central_differences_at_natural_scale() {
    let (spec, set) = world(AdapterInit::PiSSA).expect("PiSSA init is deterministic");
    let mut model = GradModel::new(&spec, &set).unwrap();
    check_all_groups(&mut model, &pairs(24), "pissa");
}

#[test]
fn gradients_match_central_differences_in_the_saturated_near_singular_regime() {
    // adapters from the near-singular calibration regime, then blown up
    // ×5 per factor (×25 on ΔW): hidden states leave the base model's
    // scale, gates and SiLUs saturate, RMS-norms see large inputs
    let (spec, set) = world(AdapterInit::CoalaA2).expect("α=2 init is inversion-free");
    let mut model = GradModel::new(&spec, &set).unwrap();
    for pi in 0..model.n_projs() {
        let (a, b) = model.adapter_at_mut(pi);
        for v in a.data.iter_mut() {
            *v *= 5.0;
        }
        for v in b.data.iter_mut() {
            *v *= 5.0;
        }
    }
    let ps = pairs(24);
    assert!(model.loss(&ps).unwrap().is_finite(), "stressed forward must stay finite");
    check_all_groups(&mut model, &ps, "saturated");
}

#[test]
fn gradients_match_central_differences_for_the_gram_inverting_init() {
    // CorDA explicitly inverts the Gram matrix; in the low-data regime
    // its factors are extreme or outright non-finite.  When the init
    // survives, the backward must still be exact on it; when it
    // collapses, that is Table 4's reported failure, not a gradient bug.
    match world(AdapterInit::CorDA) {
        Some((spec, set)) => {
            let mut model = GradModel::new(&spec, &set).unwrap();
            check_all_groups(&mut model, &pairs(24), "corda");
        }
        None => eprintln!(
            "skipped: CorDA init collapsed at this seed (the Table 4 low-data failure)"
        ),
    }
}

#[test]
fn fp64_loss_matches_the_f32_host_evaluator() {
    let (spec, set) = world(AdapterInit::CoalaA1).unwrap();
    let corpus = Corpus::synthetic(spec.vocab, 4096, SEED);
    let pool = corpus
        .train_batches("ft_train", spec.batch, spec.seq_len, 3, 11)
        .unwrap();
    let ps = coala::eval::pool_pairs(&spec, &pool).unwrap();
    let model = GradModel::new(&spec, &set).unwrap();
    let f64_loss = model.loss(&ps).unwrap();
    let f32_loss = coala::eval::pool_nll_host(&spec, &set.merged().unwrap(), &pool).unwrap();
    let gap = (f64_loss - f32_loss).abs();
    assert!(
        gap < 1e-3 * f64_loss.abs().max(1.0),
        "fp64 training loss {f64_loss} vs f32 eval loss {f32_loss} (gap {gap})"
    );
}
