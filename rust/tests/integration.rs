//! Integration tests across the full stack (artifacts required; these
//! are the `cargo test` gates `make test` runs after `make artifacts`).

use coala::calib::dataset::{Corpus, TaskBank};
use coala::coala::{Method, MuRule};
use coala::coordinator::{CompressionJob, Pipeline};
use coala::eval::{eval_tasks, perplexity};
use coala::model::ModelWeights;
use coala::runtime::{conformance, Executor};
use coala::tensor::ops::context_rel_err;
use coala::tensor::Matrix;
use coala::util::prop::assert_prop;

/// Artifact gate: executing artifacts needs both the files and the pjrt
/// feature.  `require_artifacts` eprintln-reports the skip so CI logs
/// show true coverage instead of silently counting these as passed.
fn have_artifacts(test: &str) -> bool {
    coala::runtime::require_artifacts(test)
}

#[test]
fn conformance_suite_is_green() {
    if !have_artifacts("integration::conformance_suite_is_green") {
        return;
    }
    let results = conformance::run_all("artifacts").unwrap();
    for r in &results {
        assert!(r.pass, "{}: {:.2e} > {:.0e}", r.name, r.worst_rel, r.tol);
    }
}

#[test]
fn device_and_host_coala_agree_on_model_weights() {
    if !have_artifacts("integration::device_and_host_coala_agree_on_model_weights") {
        return;
    }
    // property test over real trained projections: the PJRT factorize
    // artifact and the host f64 implementation must attain the same
    // context error at random ranks.
    let ex = Executor::new("artifacts").unwrap();
    let spec = ex.manifest.config("tiny").unwrap().clone();
    let w = ModelWeights::load("artifacts", &spec).unwrap();
    let n = spec.d_model;
    let c = spec.chunk_cols();
    let projections: Vec<String> =
        spec.compressible.iter().filter(|p| p.contains("wq") || p.contains("wv")).cloned().collect();
    assert_prop(
        "device-host-parity",
        3,
        6,
        |rng| (rng.below(projections.len()), 1 + rng.below(n / 2)),
        |&(pi, rank)| {
            let wm = w.matrix(&projections[pi]).map_err(|e| e.to_string())?;
            let chunk = Matrix::<f32>::randn(c, n, (pi * 1000 + rank) as u64);
            let r = coala::runtime::ops::tsqr_step(&ex, &Matrix::zeros(n, n), &chunk)
                .map_err(|e| e.to_string())?;
            let dev = coala::runtime::ops::factorize(&ex, &wm, &r).map_err(|e| e.to_string())?;
            let x = chunk.transpose();
            let wd = dev.truncate(rank).reconstruct().map_err(|e| e.to_string())?;
            let e_dev = context_rel_err(&wm, &wd, &x).map_err(|e| e.to_string())?;
            let host = coala::coala::coala_from_x(&wm.cast::<f64>(), &x.cast::<f64>(), 40)
                .map_err(|e| e.to_string())?;
            let wh = host.truncate(rank).reconstruct().map_err(|e| e.to_string())?;
            let e_host =
                context_rel_err(&wm.cast::<f64>(), &wh, &x.cast::<f64>()).map_err(|e| e.to_string())?;
            if (e_dev - e_host).abs() > 2e-3 + 0.01 * e_host {
                return Err(format!("rank {rank}: device {e_dev} vs host {e_host}"));
            }
            Ok(())
        },
    );
}

#[test]
fn compression_quality_ordering_holds() {
    if !have_artifacts("integration::compression_quality_ordering_holds") {
        return;
    }
    // The paper's core empirical claim, end to end: at a fixed budget the
    // context-aware optimal methods (COALA) must beat context-free SVD
    // on perplexity of the compressed model.
    let ex = Executor::new("artifacts").unwrap();
    let corpus = Corpus::load("artifacts").unwrap();
    let spec = ex.manifest.config("tiny").unwrap().clone();
    let w = ModelWeights::load("artifacts", &spec).unwrap();
    let pipe = Pipeline::new(&ex, spec.clone(), &w);
    let val = corpus.split("val").unwrap();

    let mut ppls = std::collections::BTreeMap::new();
    for (label, m) in [
        ("coala", Method::Coala(MuRule::None)),
        ("coala_reg", Method::Coala(MuRule::Adaptive { lambda: 3.0 })),
        ("plain_svd", Method::PlainSvd),
    ] {
        let mut job = CompressionJob::new("tiny", m, 0.4);
        job.calib_batches = 4;
        let out = pipe.run(&job, &corpus).unwrap();
        let rec = out.model.reconstruct_into(&w).unwrap();
        ppls.insert(label, perplexity(&ex, &spec, &rec, val, 3).unwrap());
    }
    assert!(
        ppls["coala"] < ppls["plain_svd"],
        "context-aware must beat context-free: {ppls:?}"
    );
    assert!(ppls["coala_reg"] < ppls["plain_svd"] * 1.05, "{ppls:?}");
}

#[test]
fn compressed_model_keeps_probe_signal_at_high_ratio() {
    if !have_artifacts("integration::compressed_model_keeps_probe_signal_at_high_ratio") {
        return;
    }
    let ex = Executor::new("artifacts").unwrap();
    let corpus = Corpus::load("artifacts").unwrap();
    let spec = ex.manifest.config("tiny").unwrap().clone();
    let w = ModelWeights::load("artifacts", &spec).unwrap();
    let bank = TaskBank::load("artifacts", "base", &ex.manifest.task_names).unwrap();
    let base = eval_tasks(&ex, &spec, &w, &bank, Some(256)).unwrap().average();

    let pipe = Pipeline::new(&ex, spec.clone(), &w);
    let mut job = CompressionJob::new("tiny", Method::Coala(MuRule::Adaptive { lambda: 3.0 }), 0.8);
    job.calib_batches = 4;
    let out = pipe.run(&job, &corpus).unwrap();
    let rec = out.model.reconstruct_into(&w).unwrap();
    let comp = eval_tasks(&ex, &spec, &rec, &bank, Some(256)).unwrap().average();
    // keeping 80 % of the projection params must retain most signal
    assert!(comp > base - 15.0, "base {base} compressed {comp}");
    assert!(comp > 30.0, "compressed model lost the task signal: {comp}");
}
