//! Property tests for the host linalg invariants, driven by the
//! miniature `util::prop` harness (seeded generation + greedy
//! shrinking).  These are the numerical contracts the stability claims
//! rest on, pinned with no artifacts and no PJRT:
//!
//! * Householder QR: QᵀQ ≈ I and A ≈ Q·R;
//! * the compact-WY blocked QR agrees with an unblocked column-sweep
//!   reference (same reflector convention) up to row signs, across
//!   panel-boundary sizes and rank-deficient inputs;
//! * streaming (`TsqrFolder`) and tree TSQR R-factors agree with the
//!   direct QR of the stacked matrix up to row signs;
//! * Jacobi eigh reconstructs its input (V·Λ·Vᵀ ≈ S, VᵀV ≈ I);
//! * the blocked round-robin Jacobi SVD matches the cyclic-sweep
//!   reference (singular values to fp tolerance, factors orthonormal,
//!   A ≈ U·Σ·Vᵀ) on tall, square, wide, and rank-deficient inputs, and
//!   its output is bitwise independent of the worker count;
//! * triangular solves round-trip (solve(U, U·X) ≈ X, both triangles).

use coala::linalg::{
    eigh, householder_qr, householder_qr_r, jacobi_svd, jacobi_svd_cyclic,
    jacobi_svd_with_workers, qr_r_square, solve_lower, solve_upper, tsqr_sequential,
    tsqr_tree,
};
use coala::tensor::ops::{fro, gram_t, matmul};
use coala::tensor::Matrix;
use coala::util::prop::assert_prop;

/// Flip row signs so the diagonal is non-negative — QR's R is unique up
/// to exactly this transformation.
fn normalize_row_signs(r: &Matrix<f64>) -> Matrix<f64> {
    let mut out = r.clone();
    for i in 0..out.rows.min(out.cols) {
        if out.get(i, i) < 0.0 {
            for j in 0..out.cols {
                out.set(i, j, -out.get(i, j));
            }
        }
    }
    out
}

#[test]
fn qr_orthogonality_and_reconstruction() {
    assert_prop(
        "qr-QtQ-and-A-eq-QR",
        17,
        8,
        |rng| (1 + rng.below(10), rng.below(16), rng.below(1000)),
        |&(n, extra, seed)| {
            if n == 0 {
                return Ok(()); // shrinking can zero the dimension
            }
            let m = n + extra;
            let a: Matrix<f64> = Matrix::randn(m, n, seed as u64);
            let (q, r) = householder_qr(&a).map_err(|e| e.to_string())?;
            let qtq = matmul(&q.transpose(), &q).map_err(|e| e.to_string())?;
            for i in 0..n {
                for j in 0..n {
                    let want = if i == j { 1.0 } else { 0.0 };
                    let got = qtq.get(i, j);
                    if (got - want).abs() > 1e-9 {
                        return Err(format!("QᵀQ[{i}][{j}] = {got}"));
                    }
                }
            }
            let qr = matmul(&q, &r).map_err(|e| e.to_string())?;
            let err = fro(&qr.sub(&a).map_err(|e| e.to_string())?);
            if err > 1e-9 * (1.0 + fro(&a)) {
                return Err(format!("‖A − QR‖ = {err}"));
            }
            // R upper triangular
            for i in 0..r.rows {
                for j in 0..i {
                    if r.get(i, j) != 0.0 {
                        return Err(format!("R[{i}][{j}] below diagonal nonzero"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Unblocked column-by-column Householder sweep — the pre-blocking
/// algorithm, kept as the reference the compact-WY panel factorization
/// must reproduce (same reflector convention: α = −sign(x₀)·‖x‖, zero
/// columns skipped; the lower triangle is zero-filled like
/// `householder_qr_r`).
fn qr_r_unblocked_ref(a: &Matrix<f64>) -> Matrix<f64> {
    let (m, n) = (a.rows, a.cols);
    let mut acc = a.clone();
    let mut v = vec![0.0f64; m];
    for j in 0..m.min(n) {
        let mut norm2 = 0.0;
        for i in j..m {
            let x = acc.get(i, j);
            norm2 += x * x;
        }
        let normx = norm2.sqrt();
        if normx == 0.0 {
            continue;
        }
        let alpha = if acc.get(j, j) >= 0.0 { -normx } else { normx };
        for i in j..m {
            v[i] = acc.get(i, j);
        }
        v[j] -= alpha;
        let mut vnorm2 = 0.0;
        for &x in v.iter().take(m).skip(j) {
            vnorm2 += x * x;
        }
        if vnorm2 <= 0.0 {
            continue;
        }
        let beta = 2.0 / vnorm2;
        for c in j..n {
            let mut dot = 0.0;
            for i in j..m {
                dot += v[i] * acc.get(i, c);
            }
            let s = beta * dot;
            for i in j..m {
                let cur = acc.get(i, c);
                acc.set(i, c, cur - v[i] * s);
            }
        }
    }
    let k = m.min(n);
    let mut r = Matrix::zeros(k, n);
    for i in 0..k {
        for j in i..n {
            r.set(i, j, acc.get(i, j));
        }
    }
    r
}

#[test]
fn blocked_qr_matches_unblocked_reference() {
    assert_prop(
        "blocked-qr-vs-unblocked",
        53,
        8,
        // sizes cross the NB = 32 panel boundary in both dimensions and
        // include wide (m < n) shapes, which exercise the
        // trailing-update-only tail
        |rng| (1 + rng.below(90), 1 + rng.below(90), rng.below(1000)),
        |&(m, n, seed)| {
            if m == 0 || n == 0 {
                return Ok(()); // shrinking can zero a dimension
            }
            let mut a: Matrix<f64> = Matrix::randn(m, n, seed as u64);
            if n > 2 {
                // an exactly-zero column: both sweeps must skip its
                // reflector identically, leaving a zero diagonal
                for i in 0..m {
                    a.set(i, n / 2, 0.0);
                }
            }
            let got = normalize_row_signs(&householder_qr_r(&a));
            let want = normalize_row_signs(&qr_r_unblocked_ref(&a));
            if (got.rows, got.cols) != (want.rows, want.cols) {
                return Err(format!("shape {}x{}", got.rows, got.cols));
            }
            let err = fro(&got.sub(&want).map_err(|e| e.to_string())?);
            if err > 1e-9 * (1.0 + fro(&want)) {
                return Err(format!("‖R_blocked − R_unblocked‖ = {err}"));
            }
            Ok(())
        },
    );
}

#[test]
fn blocked_qr_panel_boundaries_match_reference() {
    // fixed sizes straddling the NB = 32 panel width: one panel minus a
    // column, exactly one, one extra, multi-panel tall, and wide
    for (m, n) in [(31, 31), (32, 32), (33, 33), (64, 33), (65, 64), (40, 96), (96, 80)] {
        let a: Matrix<f64> = Matrix::randn(m, n, (m * 1000 + n) as u64);
        let got = normalize_row_signs(&householder_qr_r(&a));
        let want = normalize_row_signs(&qr_r_unblocked_ref(&a));
        assert_eq!((got.rows, got.cols), (want.rows, want.cols), "{m}x{n}");
        let err = fro(&got.sub(&want).unwrap());
        assert!(
            err < 1e-9 * (1.0 + fro(&want)),
            "{m}x{n}: ‖R_blocked − R_unblocked‖ = {err}"
        );
    }
}

#[test]
fn blocked_qr_survives_rank_deficiency() {
    // duplicated + zero columns spread across panels: beyond exact-zero
    // remainders R is no longer unique up to row signs (reflectors built
    // from roundoff-level remainders are direction-arbitrary), so pin
    // the QR contract instead: RᵀR = AᵀA, QᵀQ = I, A = QR.
    let mut a: Matrix<f64> = Matrix::randn(48, 40, 9);
    for i in 0..48 {
        a.set(i, 5, 0.0);
        let dup = a.get(i, 7);
        a.set(i, 20, dup);
        let dup2 = a.get(i, 11);
        a.set(i, 37, dup2);
    }
    let r = householder_qr_r(&a);
    let rtr = matmul(&r.transpose(), &r).unwrap();
    let ata = gram_t(&a);
    let gram_err = fro(&rtr.sub(&ata).unwrap());
    assert!(gram_err < 1e-8 * (1.0 + fro(&ata)), "‖RᵀR − AᵀA‖ = {gram_err}");
    let (q, rq) = householder_qr(&a).unwrap();
    let qtq = matmul(&q.transpose(), &q).unwrap();
    for i in 0..40 {
        for j in 0..40 {
            let want = if i == j { 1.0 } else { 0.0 };
            let got = qtq.get(i, j);
            assert!((got - want).abs() < 1e-9, "QᵀQ[{i}][{j}] = {got}");
        }
    }
    let rec_err = fro(&matmul(&q, &rq).unwrap().sub(&a).unwrap());
    assert!(rec_err < 1e-9 * (1.0 + fro(&a)), "‖A − QR‖ = {rec_err}");
}

#[test]
fn tsqr_agrees_with_direct_qr_up_to_row_signs() {
    assert_prop(
        "tsqr-vs-direct-qr",
        23,
        8,
        |rng| (1 + rng.below(8), 1 + rng.below(4), rng.below(1000)),
        |&(n, n_chunks, seed)| {
            if n == 0 || n_chunks == 0 {
                return Ok(());
            }
            let rows = n + 3; // tall chunks
            let chunks: Vec<Matrix<f64>> = (0..n_chunks)
                .map(|i| Matrix::randn(rows, n, seed as u64 * 100 + i as u64))
                .collect();
            let mut full = chunks[0].clone();
            for c in &chunks[1..] {
                full = full.vstack(c).map_err(|e| e.to_string())?;
            }
            let direct =
                normalize_row_signs(&qr_r_square(&full).map_err(|e| e.to_string())?);
            let scale = 1.0 + fro(&direct);
            for (label, r) in [
                ("sequential", tsqr_sequential(&chunks).map_err(|e| e.to_string())?),
                ("tree", tsqr_tree(&chunks, 3).map_err(|e| e.to_string())?),
            ] {
                let r = normalize_row_signs(&r);
                if (r.rows, r.cols) != (direct.rows, direct.cols) {
                    return Err(format!("{label}: shape {}x{}", r.rows, r.cols));
                }
                let err = fro(&r.sub(&direct).map_err(|e| e.to_string())?);
                if err > 1e-8 * scale {
                    return Err(format!("{label}: ‖R − R_direct‖ = {err}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn eigh_reconstructs_symmetric_input() {
    assert_prop(
        "eigh-VLVt-eq-S",
        31,
        8,
        |rng| (1 + rng.below(8), rng.below(1000)),
        |&(n, seed)| {
            if n == 0 {
                return Ok(());
            }
            let a: Matrix<f64> = Matrix::randn(n + 2, n, seed as u64);
            let s = gram_t(&a); // SPD, symmetric by construction
            let (lam, v) = eigh(&s, 60).map_err(|e| e.to_string())?;
            // VᵀV = I
            let vtv = matmul(&v.transpose(), &v).map_err(|e| e.to_string())?;
            for i in 0..n {
                for j in 0..n {
                    let want = if i == j { 1.0 } else { 0.0 };
                    if (vtv.get(i, j) - want).abs() > 1e-8 {
                        return Err(format!("VᵀV[{i}][{j}] = {}", vtv.get(i, j)));
                    }
                }
            }
            // V·Λ·Vᵀ = S
            let mut vl = v.clone();
            for i in 0..n {
                for j in 0..n {
                    vl.set(i, j, v.get(i, j) * lam[j]);
                }
            }
            let rec = matmul(&vl, &v.transpose()).map_err(|e| e.to_string())?;
            let err = fro(&rec.sub(&s).map_err(|e| e.to_string())?);
            if err > 1e-8 * (1.0 + fro(&s)) {
                return Err(format!("‖VΛVᵀ − S‖ = {err}"));
            }
            // eigenvalues of a Gram matrix are non-negative (up to roundoff)
            if lam.iter().any(|l| *l < -1e-9 * (1.0 + fro(&s))) {
                return Err(format!("negative eigenvalue: {lam:?}"));
            }
            Ok(())
        },
    );
}

/// The SVD contract checks shared by the property tests below: factors
/// orthonormal, σ descending and non-negative, and A ≈ U·Σ·Vᵀ.
fn check_svd_contract(
    a: &Matrix<f64>,
    svd: &coala::linalg::Svd<f64>,
    label: &str,
) -> Result<(), String> {
    let k = a.rows.min(a.cols);
    if (svd.u.rows, svd.u.cols) != (a.rows, k) || (svd.v.rows, svd.v.cols) != (a.cols, k) {
        return Err(format!("{label}: factor shapes"));
    }
    for (f, name) in [(&svd.u, "UᵀU"), (&svd.v, "VᵀV")] {
        let g = matmul(&f.transpose(), f).map_err(|e| e.to_string())?;
        for i in 0..k {
            for j in 0..k {
                let want = if i == j { 1.0 } else { 0.0 };
                // a zero singular value leaves its U column zero, so
                // only require orthonormality where σ is nonzero
                if name == "UᵀU" && (svd.s[i] == 0.0 || svd.s[j] == 0.0) {
                    continue;
                }
                if (g.get(i, j) - want).abs() > 1e-8 {
                    return Err(format!("{label}: {name}[{i}][{j}] = {}", g.get(i, j)));
                }
            }
        }
    }
    for w in svd.s.windows(2) {
        if w[0] < w[1] {
            return Err(format!("{label}: σ not descending: {:?}", svd.s));
        }
    }
    if svd.s.iter().any(|s| *s < 0.0) {
        return Err(format!("{label}: negative σ"));
    }
    let mut us = svd.u.clone();
    for j in 0..k {
        for i in 0..a.rows {
            us.set(i, j, us.get(i, j) * svd.s[j]);
        }
    }
    let rec = matmul(&us, &svd.v.transpose()).map_err(|e| e.to_string())?;
    let err = fro(&rec.sub(a).map_err(|e| e.to_string())?);
    if err > 1e-8 * (1.0 + fro(a)) {
        return Err(format!("{label}: ‖A − UΣVᵀ‖ = {err}"));
    }
    Ok(())
}

#[test]
fn blocked_jacobi_svd_matches_cyclic_reference() {
    assert_prop(
        "blocked-svd-vs-cyclic",
        67,
        8,
        // tall, square, and wide shapes; a zeroed column for rank
        // deficiency on larger inputs
        |rng| (1 + rng.below(24), 1 + rng.below(24), rng.below(1000)),
        |&(m, n, seed)| {
            if m == 0 || n == 0 {
                return Ok(()); // shrinking can zero a dimension
            }
            let mut a: Matrix<f64> = Matrix::randn(m, n, seed as u64);
            if n > 3 {
                for i in 0..m {
                    a.set(i, n / 2, 0.0);
                }
            }
            let blocked = jacobi_svd(&a, 60).map_err(|e| e.to_string())?;
            check_svd_contract(&a, &blocked, "blocked")?;
            // reference: the cyclic sweep (transposed for wide inputs —
            // singular values are transpose-invariant)
            let reference = if m >= n {
                jacobi_svd_cyclic(&a, 60).map_err(|e| e.to_string())?
            } else {
                jacobi_svd_cyclic(&a.transpose(), 60).map_err(|e| e.to_string())?
            };
            let scale = 1.0 + reference.s.first().copied().unwrap_or(0.0);
            for (i, (b, r)) in blocked.s.iter().zip(&reference.s).enumerate() {
                if (b - r).abs() > 1e-9 * scale {
                    return Err(format!("σ[{i}]: blocked {b} vs cyclic {r}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn blocked_jacobi_svd_is_bitwise_worker_independent() {
    assert_prop(
        "blocked-svd-worker-bits",
        71,
        6,
        |rng| (1 + rng.below(30), 1 + rng.below(20), 2 + rng.below(7), rng.below(1000)),
        |&(m, n, w, seed)| {
            if m == 0 || n == 0 || w < 2 {
                return Ok(());
            }
            let a: Matrix<f64> = Matrix::randn(m, n, seed as u64);
            let one = jacobi_svd_with_workers(&a, 60, 1).map_err(|e| e.to_string())?;
            let many = jacobi_svd_with_workers(&a, 60, w).map_err(|e| e.to_string())?;
            let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<u64>>();
            if bits(&one.s) != bits(&many.s) {
                return Err(format!("σ bits differ at w={w}"));
            }
            if bits(&one.u.data) != bits(&many.u.data) || bits(&one.v.data) != bits(&many.v.data)
            {
                return Err(format!("factor bits differ at w={w}"));
            }
            Ok(())
        },
    );
}

#[test]
fn triangular_solves_round_trip() {
    assert_prop(
        "triangular-round-trip",
        41,
        8,
        |rng| (1 + rng.below(8), 1 + rng.below(5), rng.below(1000)),
        |&(n, k, seed)| {
            if n == 0 || k == 0 {
                return Ok(());
            }
            // well-conditioned triangle: QR's R with the diagonal pushed
            // away from zero
            let a: Matrix<f64> = Matrix::randn(n + 2, n, seed as u64);
            let mut u = qr_r_square(&a).map_err(|e| e.to_string())?;
            for i in 0..n {
                let d = u.get(i, i);
                let sign = if d >= 0.0 { 1.0 } else { -1.0 };
                u.set(i, i, sign * (d.abs() + 1.0));
            }
            let x: Matrix<f64> = Matrix::randn(n, k, seed as u64 + 7);
            let b = matmul(&u, &x).map_err(|e| e.to_string())?;
            let got = solve_upper(&u, &b).map_err(|e| e.to_string())?;
            let err = fro(&got.sub(&x).map_err(|e| e.to_string())?);
            if err > 1e-9 * (1.0 + fro(&x)) {
                return Err(format!("upper round-trip err {err}"));
            }
            let l = u.transpose();
            let bl = matmul(&l, &x).map_err(|e| e.to_string())?;
            let got = solve_lower(&l, &bl).map_err(|e| e.to_string())?;
            let err = fro(&got.sub(&x).map_err(|e| e.to_string())?);
            if err > 1e-9 * (1.0 + fro(&x)) {
                return Err(format!("lower round-trip err {err}"));
            }
            Ok(())
        },
    );
}
