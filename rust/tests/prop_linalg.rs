//! Property tests for the host linalg invariants, driven by the
//! miniature `util::prop` harness (seeded generation + greedy
//! shrinking).  These are the numerical contracts the stability claims
//! rest on, pinned with no artifacts and no PJRT:
//!
//! * Householder QR: QᵀQ ≈ I and A ≈ Q·R;
//! * streaming (`TsqrFolder`) and tree TSQR R-factors agree with the
//!   direct QR of the stacked matrix up to row signs;
//! * Jacobi eigh reconstructs its input (V·Λ·Vᵀ ≈ S, VᵀV ≈ I);
//! * triangular solves round-trip (solve(U, U·X) ≈ X, both triangles).

use coala::linalg::{
    eigh, householder_qr, qr_r_square, solve_lower, solve_upper, tsqr_sequential, tsqr_tree,
};
use coala::tensor::ops::{fro, gram_t, matmul};
use coala::tensor::Matrix;
use coala::util::prop::assert_prop;

/// Flip row signs so the diagonal is non-negative — QR's R is unique up
/// to exactly this transformation.
fn normalize_row_signs(r: &Matrix<f64>) -> Matrix<f64> {
    let mut out = r.clone();
    for i in 0..out.rows.min(out.cols) {
        if out.get(i, i) < 0.0 {
            for j in 0..out.cols {
                out.set(i, j, -out.get(i, j));
            }
        }
    }
    out
}

#[test]
fn qr_orthogonality_and_reconstruction() {
    assert_prop(
        "qr-QtQ-and-A-eq-QR",
        17,
        8,
        |rng| (1 + rng.below(10), rng.below(16), rng.below(1000)),
        |&(n, extra, seed)| {
            if n == 0 {
                return Ok(()); // shrinking can zero the dimension
            }
            let m = n + extra;
            let a: Matrix<f64> = Matrix::randn(m, n, seed as u64);
            let (q, r) = householder_qr(&a).map_err(|e| e.to_string())?;
            let qtq = matmul(&q.transpose(), &q).map_err(|e| e.to_string())?;
            for i in 0..n {
                for j in 0..n {
                    let want = if i == j { 1.0 } else { 0.0 };
                    let got = qtq.get(i, j);
                    if (got - want).abs() > 1e-9 {
                        return Err(format!("QᵀQ[{i}][{j}] = {got}"));
                    }
                }
            }
            let qr = matmul(&q, &r).map_err(|e| e.to_string())?;
            let err = fro(&qr.sub(&a).map_err(|e| e.to_string())?);
            if err > 1e-9 * (1.0 + fro(&a)) {
                return Err(format!("‖A − QR‖ = {err}"));
            }
            // R upper triangular
            for i in 0..r.rows {
                for j in 0..i {
                    if r.get(i, j) != 0.0 {
                        return Err(format!("R[{i}][{j}] below diagonal nonzero"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn tsqr_agrees_with_direct_qr_up_to_row_signs() {
    assert_prop(
        "tsqr-vs-direct-qr",
        23,
        8,
        |rng| (1 + rng.below(8), 1 + rng.below(4), rng.below(1000)),
        |&(n, n_chunks, seed)| {
            if n == 0 || n_chunks == 0 {
                return Ok(());
            }
            let rows = n + 3; // tall chunks
            let chunks: Vec<Matrix<f64>> = (0..n_chunks)
                .map(|i| Matrix::randn(rows, n, seed as u64 * 100 + i as u64))
                .collect();
            let mut full = chunks[0].clone();
            for c in &chunks[1..] {
                full = full.vstack(c).map_err(|e| e.to_string())?;
            }
            let direct =
                normalize_row_signs(&qr_r_square(&full).map_err(|e| e.to_string())?);
            let scale = 1.0 + fro(&direct);
            for (label, r) in [
                ("sequential", tsqr_sequential(&chunks).map_err(|e| e.to_string())?),
                ("tree", tsqr_tree(&chunks, 3).map_err(|e| e.to_string())?),
            ] {
                let r = normalize_row_signs(&r);
                if (r.rows, r.cols) != (direct.rows, direct.cols) {
                    return Err(format!("{label}: shape {}x{}", r.rows, r.cols));
                }
                let err = fro(&r.sub(&direct).map_err(|e| e.to_string())?);
                if err > 1e-8 * scale {
                    return Err(format!("{label}: ‖R − R_direct‖ = {err}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn eigh_reconstructs_symmetric_input() {
    assert_prop(
        "eigh-VLVt-eq-S",
        31,
        8,
        |rng| (1 + rng.below(8), rng.below(1000)),
        |&(n, seed)| {
            if n == 0 {
                return Ok(());
            }
            let a: Matrix<f64> = Matrix::randn(n + 2, n, seed as u64);
            let s = gram_t(&a); // SPD, symmetric by construction
            let (lam, v) = eigh(&s, 60).map_err(|e| e.to_string())?;
            // VᵀV = I
            let vtv = matmul(&v.transpose(), &v).map_err(|e| e.to_string())?;
            for i in 0..n {
                for j in 0..n {
                    let want = if i == j { 1.0 } else { 0.0 };
                    if (vtv.get(i, j) - want).abs() > 1e-8 {
                        return Err(format!("VᵀV[{i}][{j}] = {}", vtv.get(i, j)));
                    }
                }
            }
            // V·Λ·Vᵀ = S
            let mut vl = v.clone();
            for i in 0..n {
                for j in 0..n {
                    vl.set(i, j, v.get(i, j) * lam[j]);
                }
            }
            let rec = matmul(&vl, &v.transpose()).map_err(|e| e.to_string())?;
            let err = fro(&rec.sub(&s).map_err(|e| e.to_string())?);
            if err > 1e-8 * (1.0 + fro(&s)) {
                return Err(format!("‖VΛVᵀ − S‖ = {err}"));
            }
            // eigenvalues of a Gram matrix are non-negative (up to roundoff)
            if lam.iter().any(|l| *l < -1e-9 * (1.0 + fro(&s))) {
                return Err(format!("negative eigenvalue: {lam:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn triangular_solves_round_trip() {
    assert_prop(
        "triangular-round-trip",
        41,
        8,
        |rng| (1 + rng.below(8), 1 + rng.below(5), rng.below(1000)),
        |&(n, k, seed)| {
            if n == 0 || k == 0 {
                return Ok(());
            }
            // well-conditioned triangle: QR's R with the diagonal pushed
            // away from zero
            let a: Matrix<f64> = Matrix::randn(n + 2, n, seed as u64);
            let mut u = qr_r_square(&a).map_err(|e| e.to_string())?;
            for i in 0..n {
                let d = u.get(i, i);
                let sign = if d >= 0.0 { 1.0 } else { -1.0 };
                u.set(i, i, sign * (d.abs() + 1.0));
            }
            let x: Matrix<f64> = Matrix::randn(n, k, seed as u64 + 7);
            let b = matmul(&u, &x).map_err(|e| e.to_string())?;
            let got = solve_upper(&u, &b).map_err(|e| e.to_string())?;
            let err = fro(&got.sub(&x).map_err(|e| e.to_string())?);
            if err > 1e-9 * (1.0 + fro(&x)) {
                return Err(format!("upper round-trip err {err}"));
            }
            let l = u.transpose();
            let bl = matmul(&l, &x).map_err(|e| e.to_string())?;
            let got = solve_lower(&l, &bl).map_err(|e| e.to_string())?;
            let err = fro(&got.sub(&x).map_err(|e| e.to_string())?);
            if err > 1e-9 * (1.0 + fro(&x)) {
                return Err(format!("lower round-trip err {err}"));
            }
            Ok(())
        },
    );
}
