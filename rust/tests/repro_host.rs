//! Golden-table regression for the host-route (artifact-free) stability
//! drivers.
//!
//! Runs `repro fig1 / fig2 / g1 --route host` under COALA_REPRO_FAST=1
//! with the default fixed seed and pins three things:
//!
//! 1. **determinism** — a second run reproduces byte-identical JSON;
//! 2. **the paper's headline claims** — tolerance-based assertions on
//!    the table values (COALA tracks the fp64 reference; the
//!    reduced-precision Gram routes do not; the Gram path loses σ_min;
//!    the near-singular layer really is near-singular);
//! 3. **snapshot** — values are compared order-of-magnitude against the
//!    committed `tests/golden/stability.json`, so future PRs cannot
//!    silently degrade the numbers.  The comparison uses a per-key
//!    noise floor: below it a value is implementation rounding noise
//!    (e.g. f32 subspace rotation inside a near-degenerate σ cluster),
//!    so only the order of magnitude *above* the floor is load-bearing.
//!    If the file is missing the test recreates it from the current run
//!    (commit it to pin the numbers).
//!
//! **Snapshot provenance and the fig1 floor.**  The committed snapshot
//! was produced by `python3 python/tools/golden_stability.py` — a NumPy
//! port (LAPACK, not the crate's Jacobi kernels) — because no growth
//! environment so far has had a Rust toolchain to run the crate
//! natively (PR 3 and PR 4 both hit this; `cargo`/`rustc` absent).
//! fig1's f32-vs-fp64 errors do not transfer across implementations,
//! so they sit behind a loose 3e-2 absolute floor.  To tighten it,
//! run in any environment with a native toolchain:
//!
//! ```text
//! COALA_GOLDEN_REGEN=1 cargo test -q --test repro_host
//! git add rust/tests/golden/stability.json
//! ```
//!
//! The regenerated snapshot is tagged `"source": "crate"`, and this
//! test then automatically drops fig1's floor to 10× each recorded
//! value (absolute fig1 errors become pinned).  Until that happens the
//! loose floor is a *documented* blocker, not a silent one.
//!
//! Everything here is one #[test]: the drivers share the results/
//! directory and the COALA_REPRO_FAST env var, so sequencing matters.

use coala::util::cli::Args;
use coala::util::json::Json;

fn args_host() -> Args {
    let argv: Vec<String> =
        ["repro", "--route", "host"].iter().map(|s| s.to_string()).collect();
    Args::parse(&argv)
}

fn run_stability_drivers() -> (String, String, String) {
    let args = args_host();
    for id in ["fig1", "fig2", "g1"] {
        coala::repro::run(id, &args).unwrap_or_else(|e| panic!("repro {id}: {e}"));
    }
    let read = |id: &str| -> String {
        std::fs::read_to_string(format!("results/{id}.json"))
            .unwrap_or_else(|e| panic!("results/{id}.json: {e}"))
    };
    (read("fig1"), read("fig2"), read("g1"))
}

/// f64 value of a JSON cell; collapsed (null / non-finite) → None.
fn num(v: &Json) -> Option<f64> {
    v.as_f64().filter(|x| x.is_finite())
}


#[test]
fn host_route_stability_tables_are_deterministic_and_hold_claims() {
    std::env::set_var("COALA_REPRO_FAST", "1");

    // ---- determinism: two full runs, byte-identical dumps -----------------
    let (fig1_a, fig2_a, g1_a) = run_stability_drivers();
    let (fig1_b, fig2_b, g1_b) = run_stability_drivers();
    assert_eq!(fig1_a, fig1_b, "fig1 not deterministic");
    assert_eq!(fig2_a, fig2_b, "fig2 not deterministic");
    assert_eq!(g1_a, g1_b, "g1 not deterministic");

    // ---- fig1: COALA tracks fp64; reduced-precision Gram does not --------
    let fig1 = Json::parse(&fig1_a).unwrap();
    let rows = fig1.req("rows").unwrap().as_arr().unwrap();
    assert!(rows.len() >= 4, "fig1 has only {} rank rows", rows.len());
    let mut coala_errs = Vec::new();
    for row in rows {
        let cells = row.as_arr().unwrap();
        // [rank, e_coala_f32, e_svdllm_f32, e_svdllm_bf16, e_svdllm2_bf16]
        let e_c = num(&cells[1])
            .unwrap_or_else(|| panic!("COALA column collapsed at rank {:?}", cells[0]));
        coala_errs.push(e_c);
    }
    // COALA tracks the fp64 reference: small error at most ranks (a
    // near-degenerate spectral gap may legitimately rotate one interior
    // truncation), and tight at full rank where no gap is involved
    let small = coala_errs.iter().filter(|e| **e < 0.1).count();
    assert!(
        small * 2 >= coala_errs.len(),
        "COALA(QR,f32) deviates from the fp64 reference at most ranks: {coala_errs:?}"
    );
    // at the largest rank the bf16 Gram routes sit at/above COALA's error
    // (or have collapsed outright to null) — the Fig. 1 separation
    let last = rows.last().unwrap().as_arr().unwrap();
    let e_c = num(&last[1]).unwrap();
    assert!(e_c < 0.05, "full-rank COALA(QR,f32) off the fp64 reference: {e_c}");
    for (label, cell) in [("SVD-LLM bf16", &last[3]), ("SVD-LLM-v2 bf16", &last[4])] {
        if let Some(e) = num(cell) {
            assert!(
                e >= e_c,
                "{label} ({e}) beat the QR route ({e_c}) on near-singular data"
            );
        } // null = collapsed: the strongest form of the claim
    }

    // ---- fig2: the NearSingular layer's spectrum really drops ------------
    let fig2 = Json::parse(&fig2_a).unwrap();
    let spectra = fig2.req("spectra").unwrap().as_arr().unwrap();
    assert!(spectra.len() >= 3, "tiny must have ≥ 3 layers");
    let cond = |layer: &Json| -> f64 {
        let s = layer.as_arr().unwrap();
        let first = num(&s[0]).unwrap();
        let last = num(s.last().unwrap()).unwrap().max(1e-300);
        first / last
    };
    let (c0, c1) = (cond(&spectra[0]), cond(&spectra[1]));
    assert!(
        c1 > 10.0 * c0,
        "layer 1 (near-singular regime) cond {c1} not ≫ layer 0 cond {c0}"
    );

    // ---- g1: the Gram path loses σ_min at every precision ----------------
    let g1 = Json::parse(&g1_a).unwrap();
    let g1_rows = g1.req("rows").unwrap().as_arr().unwrap();
    assert_eq!(g1_rows.len(), 3, "g1 has fp16/bf16/fp32 rows");
    for (i, row) in g1_rows.iter().enumerate() {
        let cells = row.as_arr().unwrap();
        let exact = num(&cells[0]).unwrap();
        let via = cells[1].as_f64().unwrap_or(0.0).max(0.0);
        assert!(exact > 0.0);
        assert!(
            via < exact * 0.5,
            "g1 row {i}: Gram path kept σ_min ({via} vs exact {exact})"
        );
    }

    // ---- snapshot: absolute values pinned across PRs ---------------------
    let mut fig2_sigma = Vec::new();
    for layer in spectra {
        let s = layer.as_arr().unwrap();
        fig2_sigma.push(s[0].clone());
        fig2_sigma.push(s.last().unwrap().clone());
    }
    let snapshot = Json::obj(vec![
        // provenance marker: this snapshot came from the crate's own
        // kernels, so its fig1 values transfer exactly to future runs
        ("source", Json::Str("crate".into())),
        ("fig1_coala", Json::from_f64s(&coala_errs)),
        ("fig2_sigma", Json::Arr(fig2_sigma)),
        (
            "g1_exact",
            Json::Arr(
                g1_rows
                    .iter()
                    .map(|r| r.as_arr().unwrap()[0].clone())
                    .collect(),
            ),
        ),
    ]);
    let path = "tests/golden/stability.json";
    let regen = coala::util::env::flag("COALA_GOLDEN_REGEN").unwrap();
    let existing = if regen { None } else { std::fs::read_to_string(path).ok() };
    match existing {
        None => {
            std::fs::create_dir_all("tests/golden").unwrap();
            std::fs::write(path, snapshot.dump()).unwrap();
            eprintln!("golden snapshot written at {path} — commit it to pin the numbers");
        }
        Some(prev) => {
            let prev = Json::parse(&prev).unwrap();
            // A crate-native snapshot pins fig1 tightly (values from the
            // same kernels transfer): floor = 10× the recorded value.
            // The python-generated snapshot (no "source" key) does not —
            // fig1 errors are implementation-specific below ~3e-2, so
            // only that loose absolute floor applies (see module docs
            // for the regen recipe).
            let native = prev
                .req("source")
                .ok()
                .and_then(|s| s.as_str())
                == Some("crate");
            // g1's σ_min values are stable f64 quantities on either
            // generator, so only true zero-noise is floored
            for (key, is_fig1) in [("fig1_coala", true), ("g1_exact", false)] {
                let old = prev.req(key).unwrap().as_arr().unwrap();
                let new = snapshot.req(key).unwrap().as_arr().unwrap();
                assert_eq!(old.len(), new.len(), "{key}: row count changed");
                for (i, (o, n)) in old.iter().zip(new).enumerate() {
                    let o_raw = o.as_f64().unwrap_or(0.0);
                    let n_raw = n.as_f64().unwrap_or(0.0);
                    let ok = if is_fig1 && native {
                        // crate-native snapshot: fig1 values transfer, so
                        // the absolute pin is direct — at most 10× the
                        // recorded error (improvements always pass)
                        n_raw.abs() <= (10.0 * o_raw.abs()).max(1e-12)
                    } else {
                        // floor-then-decade: below the noise floor only
                        // the order of magnitude above it is load-bearing
                        let floor = if is_fig1 { 3e-2 } else { 1e-3 };
                        let o = o_raw.abs().max(floor);
                        let n = n_raw.abs().max(floor);
                        (o.log10() - n.log10()).abs() <= 1.0
                    };
                    assert!(ok, "{key}[{i}] regressed: {o_raw} → {n_raw}");
                }
            }
            // fig2's σ spectra are f64 quantities of fixed synthetic data
            // — pinned tightly (1 % relative: cross-libm data generation
            // differs by at most an ulp of the f32 activations, which
            // perturbs even the smallest σ far less than this; any real
            // regression moves σ by factors)
            let old = prev.req("fig2_sigma").unwrap().as_arr().unwrap();
            let new = snapshot.req("fig2_sigma").unwrap().as_arr().unwrap();
            assert_eq!(old.len(), new.len(), "fig2_sigma: row count changed");
            for (i, (o, n)) in old.iter().zip(new).enumerate() {
                let (o, n) = (o.as_f64().unwrap_or(0.0), n.as_f64().unwrap_or(0.0));
                assert!(
                    (o - n).abs() <= 1e-2 * o.abs().max(n.abs()) + 1e-9,
                    "fig2_sigma[{i}] drifted: {o} → {n}"
                );
            }
        }
    }
}
