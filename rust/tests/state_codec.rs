//! Property tests for the `calib::state` binary codec: bit-exact
//! round-trips over all four accumulator kinds — on *real* accumulated
//! states (including the nearly singular regime) and on adversarial
//! non-finite payloads — plus header (magic/version/kind) rejection.

use coala::calib::accumulate::{
    make_accumulator, AccumBackend, AccumKind, CalibState, SketchKind,
};
use coala::calib::activations::ActivationSource;
use coala::calib::state::{self, ShardState, StateNode};
use coala::calib::synthetic::{regime_for_layer, Regime, SyntheticActivations};
use coala::model::synthetic::synthetic_manifest;
use coala::tensor::lowp::Precision;
use coala::tensor::Matrix;

fn bits32(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn assert_state_bits_eq(a: &CalibState, b: &CalibState, label: &str) {
    match (a, b) {
        (CalibState::R(x), CalibState::R(y)) | (CalibState::Gram(x), CalibState::Gram(y)) => {
            assert_eq!((x.rows, x.cols), (y.rows, y.cols), "{label}: shape");
            assert_eq!(bits32(&x.data), bits32(&y.data), "{label}: payload bits");
        }
        (
            CalibState::Scales { sum_abs: x, rows: rx },
            CalibState::Scales { sum_abs: y, rows: ry },
        ) => {
            assert_eq!(rx, ry, "{label}: rows");
            let xb: Vec<u64> = x.iter().map(|v| v.to_bits()).collect();
            let yb: Vec<u64> = y.iter().map(|v| v.to_bits()).collect();
            assert_eq!(xb, yb, "{label}: fp64 bits");
        }
        (
            CalibState::Sketch { y: x, folds: fx, kind: kx },
            CalibState::Sketch { y: yv, folds: fy, kind: ky },
        ) => {
            assert_eq!(fx, fy, "{label}: fold counts");
            assert_eq!(kx, ky, "{label}: sketch kinds");
            assert_eq!((x.rows, x.cols), (yv.rows, yv.cols), "{label}: shape");
            assert_eq!(bits32(&x.data), bits32(&yv.data), "{label}: payload bits");
        }
        (CalibState::None, CalibState::None) => {}
        other => panic!("{label}: kind changed in round-trip: {other:?}"),
    }
}

fn roundtrip(state: CalibState, kind: AccumKind, label: &str) {
    let st = ShardState {
        kind,
        precision: Precision::F32,
        source: "codec-test:seed1".into(),
        total: 7,
        start: 0,
        end: 7,
        done: 7,
        nodes: vec![StateNode { layer: 1, stream: "attn".into(), level: 0, index: 3, state }],
    };
    let bytes = st.encode();
    let got = ShardState::decode(&bytes, label).unwrap();
    assert_state_bits_eq(&st.nodes[0].state, &got.nodes[0].state, label);
    // encode(decode(x)) == x: the codec is deterministic and total
    assert_eq!(bytes, got.encode(), "{label}: re-encode differs");
}

#[test]
fn real_accumulated_states_roundtrip_across_seeds_and_regimes() {
    // fold genuine synthetic activations — layer 1 is the nearly
    // singular regime, where the R factor carries the tiny values a
    // lossy codec would garble first
    let spec = synthetic_manifest().config("tiny").unwrap().clone();
    assert_eq!(regime_for_layer(1), Regime::NearSingular);
    for seed in [1u64, 7, 42] {
        let src = SyntheticActivations::new(spec.clone(), seed);
        let kinds =
            [AccumKind::RFactor, AccumKind::Gram, AccumKind::Scales, AccumKind::Sketch];
        for kind in kinds {
            for layer in [0usize, 1] {
                let chunks = src.capture_batch(0).unwrap();
                let chunk = chunks
                    .iter()
                    .find(|c| c.layer == layer && c.stream == "attn")
                    .expect("attn chunk");
                let mut acc =
                    make_accumulator(kind, chunk.xt.cols, AccumBackend::Host, Precision::F32)
                        .unwrap();
                acc.fold_chunk(&chunk.xt).unwrap();
                roundtrip(acc.finish(), kind, &format!("seed {seed} {kind:?} layer {layer}"));
            }
        }
    }
}

#[test]
fn non_finite_payloads_roundtrip_bit_exactly() {
    let mut m = Matrix::<f32>::randn(5, 5, 3);
    m.data[0] = f32::NAN;
    m.data[1] = f32::from_bits(0xffc0_0bad); // negative NaN with payload
    m.data[2] = f32::INFINITY;
    m.data[3] = f32::NEG_INFINITY;
    m.data[4] = -0.0;
    roundtrip(CalibState::R(m.clone()), AccumKind::RFactor, "non-finite R");
    roundtrip(
        CalibState::Sketch { y: m.clone(), folds: u64::MAX, kind: SketchKind::Gaussian },
        AccumKind::Sketch,
        "non-finite sketch",
    );
    roundtrip(
        CalibState::Sketch { y: m.clone(), folds: 3, kind: SketchKind::Srht },
        AccumKind::Sketch,
        "non-finite srht sketch",
    );
    roundtrip(CalibState::Gram(m), AccumKind::Gram, "non-finite Gram");
    roundtrip(
        CalibState::Scales {
            sum_abs: vec![f64::NAN, f64::from_bits(0x7ff0_dead_beef_0001), -0.0, 5e-324],
            rows: 9,
        },
        AccumKind::Scales,
        "non-finite scales",
    );
}

#[test]
fn version_and_kind_mismatches_are_rejected() {
    let st = ShardState {
        kind: AccumKind::RFactor,
        precision: Precision::F32,
        source: String::new(),
        total: 2,
        start: 0,
        end: 2,
        done: 2,
        nodes: vec![],
    };
    let good = st.encode();

    // foreign versions → rejected, names the version; version 1 (pre
    // sketch-kind byte) is ambiguous about the Ω family, so it is
    // refused too rather than guessed
    for old in [1u8, 99] {
        let mut v = good.clone();
        v[4] = old;
        let e = ShardState::decode(&v, "v.state").unwrap_err().to_string();
        assert!(e.contains(&format!("version {old}")) && e.contains("v.state"), "{e}");
    }

    // magic corruption → rejected
    let mut bad = good.clone();
    bad[1] ^= 0xff;
    assert!(ShardState::decode(&bad, "bad.state").is_err());

    // unknown accumulator-kind tag (byte 7, after magic+version+payload)
    let mut k9 = good.clone();
    k9[7] = 9;
    assert!(ShardState::decode(&k9, "k9.state").is_err());

    // a node whose state kind contradicts the shard header → rejected
    let mixed = ShardState {
        kind: AccumKind::RFactor,
        precision: Precision::F32,
        source: String::new(),
        total: 2,
        start: 0,
        end: 2,
        done: 2,
        nodes: vec![StateNode {
            layer: 0,
            stream: "attn".into(),
            level: 0,
            index: 0,
            state: CalibState::Sketch {
                y: Matrix::zeros(2, 3),
                folds: 1,
                kind: SketchKind::Gaussian,
            },
        }],
    };
    assert!(ShardState::decode(&mixed.encode(), "mixed.state").is_err());

    // payload-kind confusion in both directions
    let factors = state::encode_factors(&coala::model::CompressedModel::new("tiny"));
    assert!(ShardState::decode(&factors, "f.state").is_err());
    assert!(state::decode_factors(&good, "s.state").is_err());
    assert!(state::decode_adapters(&good, "a.state").is_err());

    // every truncation point fails loudly rather than misreading
    for cut in 0..good.len() {
        assert!(
            ShardState::decode(&good[..cut], "cut.state").is_err(),
            "decode accepted a {cut}-byte prefix"
        );
    }
}

#[test]
fn unknown_sketch_kind_byte_is_rejected() {
    let mk = |kind| ShardState {
        kind: AccumKind::Sketch,
        precision: Precision::F32,
        source: "codec-test:seed1".into(),
        total: 1,
        start: 0,
        end: 1,
        done: 1,
        nodes: vec![StateNode {
            layer: 0,
            stream: "attn".into(),
            level: 0,
            index: 0,
            state: CalibState::Sketch { y: Matrix::zeros(2, 3), folds: 1, kind },
        }],
    };
    let g = mk(SketchKind::Gaussian).encode();
    let s = mk(SketchKind::Srht).encode();
    // the kind is exactly one byte of the payload — locate it by diff
    assert_eq!(g.len(), s.len());
    let diffs: Vec<usize> = (0..g.len()).filter(|&i| g[i] != s[i]).collect();
    assert_eq!(diffs.len(), 1, "kind tag must be exactly one byte: {diffs:?}");
    let mut bad = g.clone();
    bad[diffs[0]] = 9;
    let e = ShardState::decode(&bad, "k.state").unwrap_err().to_string();
    assert!(e.contains("sketch-kind") && e.contains("k.state"), "{e}");
}

#[test]
fn shard_files_survive_disk_and_errors_name_paths() {
    let dir = std::env::temp_dir().join(format!("coala-codec-it-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let spec = synthetic_manifest().config("tiny").unwrap().clone();
    let src = SyntheticActivations::new(spec.clone(), 5);
    let chunks = src.capture_batch(1).unwrap();
    let mut acc =
        make_accumulator(AccumKind::Gram, chunks[0].xt.cols, AccumBackend::Host, Precision::F32)
            .unwrap();
    acc.fold_chunk(&chunks[0].xt).unwrap();
    let st = ShardState {
        kind: AccumKind::Gram,
        precision: Precision::F32,
        source: "disk-test:seed5".into(),
        total: 3,
        start: 1,
        end: 2,
        done: 2,
        nodes: vec![StateNode {
            layer: chunks[0].layer,
            stream: chunks[0].stream.clone(),
            level: 0,
            index: 1,
            state: acc.finish(),
        }],
    };
    let path = dir.join("g.state");
    st.write(&path).unwrap();
    let got = ShardState::read(&path).unwrap();
    assert_state_bits_eq(&st.nodes[0].state, &got.nodes[0].state, "disk roundtrip");

    // a missing file error names the path it failed on
    let missing = dir.join("missing.state");
    let e = ShardState::read(&missing).unwrap_err().to_string();
    assert!(e.contains("missing.state"), "{e}");
    // a corrupt file error names the file, not just "bad magic"
    std::fs::write(dir.join("junk.state"), b"not a state file at all").unwrap();
    let e = ShardState::read(dir.join("junk.state")).unwrap_err().to_string();
    assert!(e.contains("junk.state"), "{e}");
    std::fs::remove_dir_all(&dir).ok();
}
