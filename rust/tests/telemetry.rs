#![cfg(feature = "telemetry")]
//! Telemetry subsystem tests (run with `--features telemetry`).
//!
//! Covers the JSONL appender schema, label escaping, torn-tail repair,
//! the disabled sink being a true no-op, and the determinism contract:
//! an engine run with telemetry enabled at workers=1 and workers=4
//! produces bitwise-identical factors and schema-identical telemetry
//! (only timing/identity fields may differ).

use coala::calib::synthetic::SyntheticActivations;
use coala::coala::compressor::{resolve, Compressor, Route};
use coala::coordinator::{CompressionJob, EnginePlan, Pipeline};
use coala::model::synthetic::{synthetic_manifest, synthetic_weights};
use coala::runtime::Executor;
use coala::telemetry::health::{self, HealthEvent};
use coala::telemetry::report::{self, ReportOptions};
use coala::telemetry::{alloc, run_id_for, trace, TelemetrySink};
use coala::util::json::Json;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Mutex;

/// The health gate is process-global; tests that toggle it or run the
/// pipeline (whose factorize stage reacts to it) serialize here so one
/// test's probes never leak into another's trace.
static HEALTH_LOCK: Mutex<()> = Mutex::new(());

/// Arm the health probes for one scope; the guard disarms on drop even
/// if the test panics.
struct HealthOn;
impl HealthOn {
    fn new() -> HealthOn {
        health::set_enabled(true);
        HealthOn
    }
}
impl Drop for HealthOn {
    fn drop(&mut self) {
        health::set_enabled(false);
    }
}

fn tmp_path(tag: &str) -> PathBuf {
    static N: AtomicU32 = AtomicU32::new(0);
    let n = N.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!("coala_tel_{}_{tag}_{n}.jsonl", std::process::id()))
}

/// Every non-empty line of the file, parsed; panics on any invalid line.
fn parsed_lines(path: &PathBuf) -> Vec<Json> {
    std::fs::read_to_string(path)
        .unwrap()
        .lines()
        .filter(|l| !l.is_empty())
        .map(|l| Json::parse(l).unwrap_or_else(|e| panic!("bad JSONL line `{l}`: {e}")))
        .collect()
}

const SCHEMA_KEYS: [&str; 10] = [
    "kind", "config", "method", "route", "accum", "run_id", "span", "workers", "shards", "pid",
];

#[test]
fn appender_emits_schema_complete_records() {
    let path = tmp_path("schema");
    {
        let sink = TelemetrySink::to_path(path.to_str().unwrap()).unwrap().with_labels(|l| {
            l.config = "tiny".into();
            l.method = "coala".into();
            l.route = "host".into();
            l.accum = "exact".into();
            l.workers = 4;
            l.shards = 2;
        });
        assert!(sink.is_enabled());
        sink.stage_s("accumulate", 0.125);
        sink.counter("batches_folded", 6);
        {
            let _t = sink.start_timer("codec_encode");
        }
    }
    let recs = parsed_lines(&path);
    assert_eq!(recs.len(), 3, "one line per emit");
    for rec in &recs {
        for key in SCHEMA_KEYS {
            assert!(rec.req(key).is_ok(), "record missing `{key}`: {rec:?}");
        }
        assert_eq!(rec.req("config").unwrap().as_str(), Some("tiny"));
        assert_eq!(rec.req("workers").unwrap().as_f64(), Some(4.0));
        assert_eq!(rec.req("shards").unwrap().as_f64(), Some(2.0));
    }
    assert_eq!(recs[0].req("stage").unwrap().as_str(), Some("accumulate"));
    assert_eq!(recs[0].req("s").unwrap().as_f64(), Some(0.125));
    assert_eq!(recs[1].req("kind").unwrap().as_str(), Some("counter"));
    assert_eq!(recs[1].req("name").unwrap().as_str(), Some("batches_folded"));
    assert_eq!(recs[1].req("value").unwrap().as_f64(), Some(6.0));
    assert_eq!(recs[2].req("stage").unwrap().as_str(), Some("codec_encode"));
    assert!(recs[2].req("s").unwrap().as_f64().unwrap() >= 0.0, "timer seconds");
    std::fs::remove_file(&path).ok();
}

#[test]
fn labels_with_quotes_and_newlines_stay_valid_json() {
    let path = tmp_path("escape");
    let weird = "we\"ird\\label\nline2\ttab";
    {
        let sink = TelemetrySink::to_path(path.to_str().unwrap())
            .unwrap()
            .with_labels(|l| l.config = weird.to_string());
        sink.stage_s("capture", 0.0);
    }
    let recs = parsed_lines(&path);
    assert_eq!(recs.len(), 1, "escaped newline must not split the record");
    assert_eq!(recs[0].req("config").unwrap().as_str(), Some(weird), "label round-trip");
    std::fs::remove_file(&path).ok();
}

#[test]
fn torn_tail_is_repaired_on_open() {
    let path = tmp_path("torn");
    // a previous writer died mid-record: no trailing newline
    std::fs::write(&path, "{\"kind\":\"stage\",\"stage\":\"capture\",\"s\":0.").unwrap();
    {
        let sink = TelemetrySink::to_path(path.to_str().unwrap()).unwrap();
        sink.stage_s("accumulate", 1.0);
    }
    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 2, "torn tail terminated, new record on its own line: {text:?}");
    // the torn line stays torn (it carries no fabricated data), but it
    // can no longer corrupt the record appended after it
    let rec = Json::parse(lines[1]).unwrap();
    assert_eq!(rec.req("stage").unwrap().as_str(), Some("accumulate"));
    assert_eq!(rec.req("s").unwrap().as_f64(), Some(1.0));
    std::fs::remove_file(&path).ok();
}

#[test]
fn disabled_sink_is_a_no_op() {
    let sink = TelemetrySink::disabled();
    assert!(!sink.is_enabled());
    // none of these may panic or touch the filesystem
    sink.stage_s("capture", 1.0);
    sink.counter("batches_folded", 1);
    let _t = sink.start_timer("trainer_step");
}

/// The determinism contract end-to-end: telemetry observes, never
/// perturbs.  workers=1 and workers=4 produce bitwise-identical
/// factors, and their telemetry differs only in timings/identity.
#[test]
fn engine_smoke_is_bitwise_identical_across_workers_with_telemetry_on() {
    let _guard = HEALTH_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let ex = Executor::from_manifest(synthetic_manifest()).unwrap();
    let spec = ex.manifest.config("tiny").unwrap().clone();
    let w = synthetic_weights(&spec, 5);
    let src = SyntheticActivations::new(spec.clone(), 5);
    let comp = resolve("coala").unwrap();
    let mut job = CompressionJob::new("tiny", comp.method(), 0.4);
    job.calib_batches = 3;

    let mut ref_factors: Option<Vec<(String, Vec<f32>, Vec<f32>)>> = None;
    let mut ref_schema: Option<Vec<String>> = None;
    let mut ref_sweeps: Option<f64> = None;
    for workers in [1usize, 4] {
        let path = tmp_path(&format!("engine_w{workers}"));
        let mut plan = EnginePlan::with_workers(workers);
        plan.telemetry =
            TelemetrySink::to_path(path.to_str().unwrap()).unwrap().with_labels(|l| {
                l.config = "tiny".into();
                l.method = comp.name();
                l.route = "host".into();
                l.accum = "exact".into();
                l.workers = workers;
                l.shards = 1;
            });
        let pipe = Pipeline::new(&ex, spec.clone(), &w).with_route(Route::Host).with_plan(plan);
        let out = pipe.run_with_source(&job, &src).unwrap();
        assert!(out.model.all_finite());
        let factors: Vec<(String, Vec<f32>, Vec<f32>)> = out
            .model
            .factors
            .iter()
            .map(|(k, f)| (k.clone(), f.a.data.clone(), f.b.data.clone()))
            .collect();
        match &ref_factors {
            None => ref_factors = Some(factors),
            Some(fw) => assert_eq!(fw, &factors, "telemetry perturbed the engine at w={workers}"),
        }

        let recs = parsed_lines(&path);
        let stages: Vec<&str> = recs
            .iter()
            .filter(|r| r.req("kind").unwrap().as_str() == Some("stage"))
            .map(|r| r.req("stage").unwrap().as_str().unwrap())
            .collect();
        for want in [
            "capture",
            "accumulate",
            "merge_reduce",
            "factorize",
            "capture_stall",
            "accum_idle",
        ] {
            assert!(stages.contains(&want), "w={workers}: stage `{want}` missing: {stages:?}");
        }
        assert!(
            recs.iter().any(|r| r.req("kind").unwrap().as_str() == Some("counter")
                && r.req("name").unwrap().as_str() == Some("projections_factorized")),
            "w={workers}: projections_factorized counter missing"
        );
        // the factorize stage reports its Jacobi convergence cost, and
        // the count — a sum of deterministic per-projection sweep
        // totals — is independent of the worker fan (this is the only
        // test in this binary that runs factorize, so the process-global
        // counter delta is not polluted by concurrent tests)
        let sweeps = recs
            .iter()
            .find(|r| r.req("kind").unwrap().as_str() == Some("counter")
                && r.req("name").unwrap().as_str() == Some("svd_sweeps"))
            .unwrap_or_else(|| panic!("w={workers}: svd_sweeps counter missing"))
            .req("value")
            .unwrap()
            .as_f64()
            .unwrap();
        assert!(sweeps >= 1.0, "w={workers}: factorize must run at least one Jacobi sweep");
        match ref_sweeps {
            None => ref_sweeps = Some(sweeps),
            Some(sw) => assert_eq!(sw, sweeps, "svd_sweeps differs at w={workers}"),
        }
        // schema fingerprint: everything except timing/identity fields
        // must be identical across worker counts
        let mut schema: Vec<String> = recs
            .iter()
            .map(|r| {
                let kind = r.req("kind").unwrap().as_str().unwrap().to_string();
                // stage/counter/health/run records key their "what" on
                // different fields; fall through so no kind can panic
                let what = ["stage", "name", "probe", "source"]
                    .iter()
                    .find_map(|k| r.req(k).ok().and_then(|v| v.as_str().map(str::to_string)))
                    .unwrap_or_default();
                let (config, method, route, accum) = (
                    r.req("config").unwrap().as_str().unwrap().to_string(),
                    r.req("method").unwrap().as_str().unwrap().to_string(),
                    r.req("route").unwrap().as_str().unwrap().to_string(),
                    r.req("accum").unwrap().as_str().unwrap().to_string(),
                );
                format!("{kind}/{what}/{config}/{method}/{route}/{accum}")
            })
            .collect();
        schema.sort();
        match &ref_schema {
            None => ref_schema = Some(schema),
            Some(sw) => assert_eq!(sw, &schema, "telemetry schema differs at w={workers}"),
        }
        std::fs::remove_file(&path).ok();
    }
}

/// Tentpole schema: `run` headers and `health` records carry the full
/// label set (run_id + span included), the header carries the raw
/// fingerprint, and a per-record span override wins over the label.
#[test]
fn run_and_health_records_are_schema_complete() {
    let path = tmp_path("runhealth");
    let fp = "tiny:Host:seed5:b3";
    {
        let sink = TelemetrySink::to_path(path.to_str().unwrap())
            .unwrap()
            .with_labels(|l| {
                l.config = "tiny".into();
                l.route = "host".into();
                l.span = "run".into();
            })
            .with_run(fp);
        sink.health_event(
            Some("factorize/l0.wq"),
            &HealthEvent::new("svd")
                .num("sweeps", 7.0)
                .num("converged", 1.0)
                .num("sigma_max", 3.5)
                .num("sigma_min", 0.25)
                .txt("family", "gaussian"),
        );
    }
    let recs = parsed_lines(&path);
    assert_eq!(recs.len(), 2, "one run header + one health record");
    let rid = run_id_for(fp);
    for rec in &recs {
        for key in SCHEMA_KEYS {
            assert!(rec.req(key).is_ok(), "record missing `{key}`: {rec:?}");
        }
        assert_eq!(rec.req("run_id").unwrap().as_str(), Some(rid.as_str()));
    }
    assert_eq!(recs[0].req("kind").unwrap().as_str(), Some("run"));
    assert_eq!(recs[0].req("source").unwrap().as_str(), Some(fp));
    assert_eq!(recs[0].req("span").unwrap().as_str(), Some("run"));
    assert_eq!(recs[1].req("kind").unwrap().as_str(), Some("health"));
    assert_eq!(recs[1].req("probe").unwrap().as_str(), Some("svd"));
    assert_eq!(recs[1].req("span").unwrap().as_str(), Some("factorize/l0.wq"), "override wins");
    assert_eq!(recs[1].req("sweeps").unwrap().as_f64(), Some(7.0));
    assert_eq!(recs[1].req("family").unwrap().as_str(), Some("gaussian"));
    std::fs::remove_file(&path).ok();
}

/// Span stitching: sinks standing in for two `coala shard` processes
/// and the `coala merge` all hash the same calibration fingerprint, so
/// every record in the shared file stamps one run_id — the trace
/// stitches with zero coordination.
#[test]
fn shard_and_merge_sinks_stitch_under_one_run_id() {
    let path = tmp_path("stitch");
    let fp = "tiny:Host:seed9:b8";
    for span in ["shard/0", "shard/1", "merge"] {
        let sink = TelemetrySink::to_path(path.to_str().unwrap())
            .unwrap()
            .with_labels(|l| {
                l.shards = 2;
                l.span = span.to_string();
            })
            .with_run(fp);
        sink.stage_s("accumulate", 0.25);
    }
    let recs = parsed_lines(&path);
    let headers = recs
        .iter()
        .filter(|r| r.req("kind").unwrap().as_str() == Some("run"))
        .count();
    assert!(headers >= 1, "at least one run header");
    let rids: std::collections::BTreeSet<String> = recs
        .iter()
        .map(|r| r.req("run_id").unwrap().as_str().unwrap().to_string())
        .collect();
    assert_eq!(rids.len(), 1, "all records share one run_id: {rids:?}");
    assert_eq!(rids.iter().next().unwrap(), &run_id_for(fp));
    let spans: std::collections::BTreeSet<String> = recs
        .iter()
        .filter(|r| r.req("kind").unwrap().as_str() == Some("stage"))
        .map(|r| r.req("span").unwrap().as_str().unwrap().to_string())
        .collect();
    for want in ["shard/0", "shard/1", "merge"] {
        assert!(spans.contains(want), "span `{want}` missing: {spans:?}");
    }
    std::fs::remove_file(&path).ok();
}

/// The backpressure blind spot is closed: with queue_cap=1 the engine
/// reports `capture_stall` and `accum_idle` stage records measured
/// around its own bounded-channel send/recv.
#[test]
fn queue_cap_one_run_reports_backpressure_stages() {
    let _guard = HEALTH_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let ex = Executor::from_manifest(synthetic_manifest()).unwrap();
    let spec = ex.manifest.config("tiny").unwrap().clone();
    let w = synthetic_weights(&spec, 7);
    let src = SyntheticActivations::new(spec.clone(), 7);
    let comp = resolve("coala").unwrap();
    let mut job = CompressionJob::new("tiny", comp.method(), 0.4);
    job.calib_batches = 3;
    let path = tmp_path("backpressure");
    let mut plan = EnginePlan::with_workers(2);
    plan.queue_cap = 1;
    plan.telemetry = TelemetrySink::to_path(path.to_str().unwrap()).unwrap();
    let pipe = Pipeline::new(&ex, spec.clone(), &w).with_route(Route::Host).with_plan(plan);
    pipe.run_with_source(&job, &src).unwrap();
    let recs = parsed_lines(&path);
    for want in ["capture_stall", "accum_idle"] {
        let rec = recs
            .iter()
            .find(|r| r.req("stage").ok().and_then(|v| v.as_str()) == Some(want))
            .unwrap_or_else(|| panic!("stage `{want}` missing"));
        let s = rec.req("s").unwrap().as_f64().unwrap();
        assert!(s >= 0.0, "{want} must be a non-negative duration, got {s}");
    }
    std::fs::remove_file(&path).ok();
}

/// The health contract end-to-end: probes fire when armed (SVD
/// convergence, R-diagonal condition, per-projection factor checks)
/// and the factors are bitwise identical with health on or off.
#[test]
fn health_probes_fire_and_never_perturb_factors() {
    let _guard = HEALTH_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let ex = Executor::from_manifest(synthetic_manifest()).unwrap();
    let spec = ex.manifest.config("tiny").unwrap().clone();
    let w = synthetic_weights(&spec, 9);
    let src = SyntheticActivations::new(spec.clone(), 9);
    let comp = resolve("coala").unwrap();
    let mut job = CompressionJob::new("tiny", comp.method(), 0.4);
    job.calib_batches = 2;

    let run = |armed: bool, tag: &str| {
        let path = tmp_path(tag);
        let guard = armed.then(HealthOn::new);
        let mut plan = EnginePlan::with_workers(2);
        plan.telemetry = TelemetrySink::to_path(path.to_str().unwrap()).unwrap();
        let pipe = Pipeline::new(&ex, spec.clone(), &w).with_route(Route::Host).with_plan(plan);
        let out = pipe.run_with_source(&job, &src).unwrap();
        drop(guard);
        let factors: Vec<(String, Vec<f32>, Vec<f32>)> = out
            .model
            .factors
            .iter()
            .map(|(k, f)| (k.clone(), f.a.data.clone(), f.b.data.clone()))
            .collect();
        let recs = parsed_lines(&path);
        std::fs::remove_file(&path).ok();
        (factors, recs)
    };

    let (off_factors, off_recs) = run(false, "health_off");
    let (on_factors, on_recs) = run(true, "health_on");
    assert_eq!(off_factors, on_factors, "health probes perturbed the factors");
    assert!(
        !off_recs.iter().any(|r| r.req("kind").unwrap().as_str() == Some("health")),
        "health records must not appear when the gate is off"
    );

    let health: Vec<&Json> = on_recs
        .iter()
        .filter(|r| r.req("kind").unwrap().as_str() == Some("health"))
        .collect();
    assert!(!health.is_empty(), "armed run emitted no health records");
    let probes: std::collections::BTreeSet<&str> = health
        .iter()
        .map(|r| r.req("probe").unwrap().as_str().unwrap())
        .collect();
    for want in ["svd", "r_cond", "factors"] {
        assert!(probes.contains(want), "probe `{want}` missing: {probes:?}");
    }
    for r in &health {
        let span = r.req("span").unwrap().as_str().unwrap();
        match r.req("probe").unwrap().as_str().unwrap() {
            "r_cond" => {
                assert!(span.starts_with("accumulate/"), "r_cond span `{span}`");
                assert!(r.req("cond").unwrap().as_f64().unwrap() >= 1.0);
            }
            "svd" => {
                assert!(span.starts_with("factorize/"), "svd span `{span}`");
                assert!(r.req("sweeps").unwrap().as_f64().unwrap() >= 1.0);
            }
            "factors" => {
                assert!(span.starts_with("factorize/"), "factors span `{span}`");
                assert_eq!(r.req("nonfinite").unwrap().as_f64(), Some(0.0));
            }
            _ => {}
        }
    }
}

/// `coala report --json` over a hand-built fixture: aggregates match,
/// u64 counters survive exactly, and a torn line is skipped with a
/// note instead of killing the analysis.
#[test]
fn report_json_matches_hand_built_fixture() {
    let path = tmp_path("report");
    // "value" below is u64::MAX verbatim — it must survive exactly
    let lines = [
        r#"{"kind":"run","run_id":"r1","source":"tiny:Host:seed1:b4"}"#,
        r#"{"kind":"stage","run_id":"r1","stage":"capture","s":1.0,"span":"shard/0","pid":11}"#,
        r#"{"kind":"stage","run_id":"r1","stage":"capture","s":3.0,"span":"shard/1","pid":12}"#,
        r#"{"kind":"stage","run_id":"r1","stage":"capture_stall","s":0.5}"#,
        r#"{"kind":"counter","run_id":"r1","name":"big","value":18446744073709551615}"#,
        r#"{"kind":"health","run_id":"r1","probe":"r_cond","cond":1.0e12}"#,
        r#"{"kind":"health","run_id":"r1","probe":"svd","converged":1.0,"sweeps":9.0}"#,
        r#"{"kind":"stage","stage":"tor"#, // torn mid-write
    ];
    std::fs::write(&path, lines.join("\n")).unwrap();

    let out = report::render(
        &[path.to_str().unwrap().to_string()],
        &ReportOptions { json: true, cond_threshold: 1e8 },
    )
    .unwrap();
    let j = Json::parse(&out).unwrap();
    assert_eq!(j.req("files").unwrap().as_u64(), Some(1));
    assert_eq!(j.req("skipped_lines").unwrap().as_u64(), Some(1), "torn line skipped with note");
    let runs = match j.req("runs").unwrap() {
        Json::Arr(v) => v,
        other => panic!("runs should be an array: {other:?}"),
    };
    assert_eq!(runs.len(), 1);
    let run = &runs[0];
    assert_eq!(run.req("run_id").unwrap().as_str(), Some("r1"));
    assert_eq!(run.req("headers").unwrap().as_u64(), Some(1));
    assert_eq!(run.req("busy_s").unwrap().as_f64(), Some(4.0));
    assert_eq!(run.req("stall_s").unwrap().as_f64(), Some(0.5));
    // u64 counters survive the full emit→parse→aggregate→dump loop
    assert_eq!(run.req("counters").unwrap().req("big").unwrap().as_u64(), Some(u64::MAX));
    let stages = match run.req("stages").unwrap() {
        Json::Arr(v) => v,
        other => panic!("stages should be an array: {other:?}"),
    };
    let capture = stages
        .iter()
        .find(|s| s.req("stage").unwrap().as_str() == Some("capture"))
        .unwrap();
    assert_eq!(capture.req("count").unwrap().as_u64(), Some(2));
    assert_eq!(capture.req("total_s").unwrap().as_f64(), Some(4.0));
    assert_eq!(capture.req("mean_s").unwrap().as_f64(), Some(2.0));
    assert_eq!(capture.req("p50_s").unwrap().as_f64(), Some(1.0));
    assert_eq!(capture.req("p99_s").unwrap().as_f64(), Some(3.0));
    assert_eq!(capture.req("skew").unwrap().as_f64(), Some(3.0), "shard/1 did 3x shard/0's work");
    let health = run.req("health").unwrap();
    assert_eq!(health.req("records").unwrap().as_u64(), Some(2));
    assert_eq!(health.req("warnings").unwrap().req("high_cond").unwrap().as_u64(), Some(1));
    assert_eq!(health.req("errors").unwrap().req("total").unwrap().as_u64(), Some(0));
    std::fs::remove_file(&path).ok();
}

/// Arm the tracking allocator for one scope; the guard disarms and
/// clears the budget on drop even if the test panics.  The allocator
/// is process-global, so tests using it serialize on [`HEALTH_LOCK`].
struct AllocOn;
impl AllocOn {
    fn new() -> AllocOn {
        alloc::set_armed(true);
        AllocOn
    }
}
impl Drop for AllocOn {
    fn drop(&mut self) {
        alloc::set_armed(false);
        alloc::set_budget(None);
    }
}

/// The memory-layer contract end-to-end: armed, every stage record
/// carries `peak_bytes`/`cur_bytes` and a tiny budget raises
/// `mem_budget` health warnings; disarmed, no memory fields appear —
/// and the factors are bitwise identical either way (the tracking
/// allocator is observation-only, like the health probes).
#[test]
fn alloc_stats_stamp_stage_records_and_never_perturb_factors() {
    let _guard = HEALTH_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let ex = Executor::from_manifest(synthetic_manifest()).unwrap();
    let spec = ex.manifest.config("tiny").unwrap().clone();
    let w = synthetic_weights(&spec, 13);
    let src = SyntheticActivations::new(spec.clone(), 13);
    let comp = resolve("coala").unwrap();
    let mut job = CompressionJob::new("tiny", comp.method(), 0.4);
    job.calib_batches = 2;

    let run = |armed: bool, tag: &str| {
        let path = tmp_path(tag);
        let guard = armed.then(AllocOn::new);
        if armed {
            // one byte: every stage peak exceeds it, so the budget
            // warning path is exercised deterministically (the env
            // knob's MiB floor lives in init_from_env, not here)
            alloc::set_budget(Some(1));
        }
        let mut plan = EnginePlan::with_workers(2);
        plan.telemetry = TelemetrySink::to_path(path.to_str().unwrap()).unwrap();
        let pipe = Pipeline::new(&ex, spec.clone(), &w).with_route(Route::Host).with_plan(plan);
        let out = pipe.run_with_source(&job, &src).unwrap();
        drop(guard);
        let factors: Vec<(String, Vec<f32>, Vec<f32>)> = out
            .model
            .factors
            .iter()
            .map(|(k, f)| (k.clone(), f.a.data.clone(), f.b.data.clone()))
            .collect();
        let recs = parsed_lines(&path);
        std::fs::remove_file(&path).ok();
        (factors, recs)
    };

    let (off_factors, off_recs) = run(false, "alloc_off");
    let (on_factors, on_recs) = run(true, "alloc_on");
    assert_eq!(off_factors, on_factors, "alloc stats perturbed the factors");

    let stages = |recs: &[Json]| -> Vec<Json> {
        recs.iter()
            .filter(|r| r.req("kind").unwrap().as_str() == Some("stage"))
            .cloned()
            .collect()
    };
    for rec in stages(&off_recs) {
        assert!(
            rec.get("peak_bytes").is_none() && rec.get("cur_bytes").is_none(),
            "disarmed stage record must carry no memory fields: {rec:?}"
        );
    }
    let on_stages = stages(&on_recs);
    assert!(!on_stages.is_empty(), "armed run emitted no stage records");
    for rec in &on_stages {
        let stage = rec.req("stage").unwrap().as_str().unwrap();
        let peak = rec
            .get("peak_bytes")
            .and_then(Json::as_u64)
            .unwrap_or_else(|| panic!("stage `{stage}` missing peak_bytes: {rec:?}"));
        // cur_bytes is read at scope exit, after frees, so presence is
        // the only invariant worth asserting on it
        let _cur = rec
            .get("cur_bytes")
            .and_then(Json::as_u64)
            .unwrap_or_else(|| panic!("stage `{stage}` missing cur_bytes: {rec:?}"));
        assert!(peak >= 1, "stage `{stage}` peak_bytes must be positive, got {peak}");
    }
    // the one-byte budget is below any real stage peak, so the run
    // must have flagged it — as a warning, never an abort (the run
    // above already succeeded)
    let budget_hits = on_recs
        .iter()
        .filter(|r| {
            r.req("kind").unwrap().as_str() == Some("health")
                && r.get("probe").and_then(Json::as_str) == Some("mem_budget")
        })
        .count();
    assert!(budget_hits >= 1, "one-byte budget produced no mem_budget warning");
    // and the allocator's process peak is bounded above by the OS HWM
    // (snapshot requires armed; re-arm briefly under the same lock)
    alloc::set_armed(true);
    let snap = alloc::snapshot();
    alloc::set_armed(false);
    if let (Some(s), Some(hwm)) = (snap, alloc::vm_hwm_bytes()) {
        assert!(hwm >= s.peak_bytes, "VmHWM {hwm} below allocator peak {}", s.peak_bytes);
    }
}

/// `coala report --trace` over a hand-built fixture diffs structurally
/// against the committed Chrome-trace golden: one complete event per
/// stage record, memory + queue-depth counter tracks, metadata naming
/// every pid/tid, torn and undrawable lines skipped.
#[test]
fn trace_export_matches_committed_golden() {
    let path = tmp_path("trace");
    let lines = [
        r#"{"kind":"run","run_id":"r1","source":"tiny:Host:seed1:b4","pid":11,"span":"shard/0","t_unix_s":100}"#,
        r#"{"kind":"stage","run_id":"r1","stage":"capture","s":2,"span":"shard/0","pid":11,"t_unix_s":103,"peak_bytes":4096,"cur_bytes":1024}"#,
        r#"{"kind":"stage","run_id":"r1","stage":"accumulate","s":1,"span":"shard/1","pid":12,"t_unix_s":103}"#,
        r#"{"kind":"counter","run_id":"r1","name":"queue_depth_hwm","value":3,"span":"shard/0","pid":11,"t_unix_s":104}"#,
        r#"{"kind":"counter","run_id":"r1","name":"svd_sweeps","value":7,"span":"shard/0","pid":11,"t_unix_s":104}"#,
        r#"{"kind":"health","run_id":"r1","probe":"svd","pid":11,"span":"shard/0"}"#,
        r#"{"kind":"stage","stage":"tor"#, // torn mid-write
    ];
    std::fs::write(&path, lines.join("\n")).unwrap();

    let out = trace::export(&[path.to_str().unwrap().to_string()]).unwrap();
    std::fs::remove_file(&path).ok();
    let got = Json::parse(&out).unwrap();
    let want = Json::parse(include_str!("golden/trace.json")).unwrap();
    assert_eq!(got, want, "trace export diverged from tests/golden/trace.json:\n{out}");

    // every well-formed stage record maps to exactly one complete event
    let events = got.req("traceEvents").unwrap().as_arr().unwrap();
    let complete =
        events.iter().filter(|e| e.req("ph").unwrap().as_str() == Some("X")).count();
    assert_eq!(complete, 2, "2 stage records -> 2 complete events");
}
