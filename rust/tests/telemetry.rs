#![cfg(feature = "telemetry")]
//! Telemetry subsystem tests (run with `--features telemetry`).
//!
//! Covers the JSONL appender schema, label escaping, torn-tail repair,
//! the disabled sink being a true no-op, and the determinism contract:
//! an engine run with telemetry enabled at workers=1 and workers=4
//! produces bitwise-identical factors and schema-identical telemetry
//! (only timing/identity fields may differ).

use coala::calib::synthetic::SyntheticActivations;
use coala::coala::compressor::{resolve, Compressor, Route};
use coala::coordinator::{CompressionJob, EnginePlan, Pipeline};
use coala::model::synthetic::{synthetic_manifest, synthetic_weights};
use coala::runtime::Executor;
use coala::telemetry::TelemetrySink;
use coala::util::json::Json;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU32, Ordering};

fn tmp_path(tag: &str) -> PathBuf {
    static N: AtomicU32 = AtomicU32::new(0);
    let n = N.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!("coala_tel_{}_{tag}_{n}.jsonl", std::process::id()))
}

/// Every non-empty line of the file, parsed; panics on any invalid line.
fn parsed_lines(path: &PathBuf) -> Vec<Json> {
    std::fs::read_to_string(path)
        .unwrap()
        .lines()
        .filter(|l| !l.is_empty())
        .map(|l| Json::parse(l).unwrap_or_else(|e| panic!("bad JSONL line `{l}`: {e}")))
        .collect()
}

const SCHEMA_KEYS: [&str; 8] =
    ["kind", "config", "method", "route", "accum", "workers", "shards", "pid"];

#[test]
fn appender_emits_schema_complete_records() {
    let path = tmp_path("schema");
    {
        let sink = TelemetrySink::to_path(path.to_str().unwrap()).unwrap().with_labels(|l| {
            l.config = "tiny".into();
            l.method = "coala".into();
            l.route = "host".into();
            l.accum = "exact".into();
            l.workers = 4;
            l.shards = 2;
        });
        assert!(sink.is_enabled());
        sink.stage_s("accumulate", 0.125);
        sink.counter("batches_folded", 6);
        {
            let _t = sink.start_timer("codec_encode");
        }
    }
    let recs = parsed_lines(&path);
    assert_eq!(recs.len(), 3, "one line per emit");
    for rec in &recs {
        for key in SCHEMA_KEYS {
            assert!(rec.req(key).is_ok(), "record missing `{key}`: {rec:?}");
        }
        assert_eq!(rec.req("config").unwrap().as_str(), Some("tiny"));
        assert_eq!(rec.req("workers").unwrap().as_f64(), Some(4.0));
        assert_eq!(rec.req("shards").unwrap().as_f64(), Some(2.0));
    }
    assert_eq!(recs[0].req("stage").unwrap().as_str(), Some("accumulate"));
    assert_eq!(recs[0].req("s").unwrap().as_f64(), Some(0.125));
    assert_eq!(recs[1].req("kind").unwrap().as_str(), Some("counter"));
    assert_eq!(recs[1].req("name").unwrap().as_str(), Some("batches_folded"));
    assert_eq!(recs[1].req("value").unwrap().as_f64(), Some(6.0));
    assert_eq!(recs[2].req("stage").unwrap().as_str(), Some("codec_encode"));
    assert!(recs[2].req("s").unwrap().as_f64().unwrap() >= 0.0, "timer seconds");
    std::fs::remove_file(&path).ok();
}

#[test]
fn labels_with_quotes_and_newlines_stay_valid_json() {
    let path = tmp_path("escape");
    let weird = "we\"ird\\label\nline2\ttab";
    {
        let sink = TelemetrySink::to_path(path.to_str().unwrap())
            .unwrap()
            .with_labels(|l| l.config = weird.to_string());
        sink.stage_s("capture", 0.0);
    }
    let recs = parsed_lines(&path);
    assert_eq!(recs.len(), 1, "escaped newline must not split the record");
    assert_eq!(recs[0].req("config").unwrap().as_str(), Some(weird), "label round-trip");
    std::fs::remove_file(&path).ok();
}

#[test]
fn torn_tail_is_repaired_on_open() {
    let path = tmp_path("torn");
    // a previous writer died mid-record: no trailing newline
    std::fs::write(&path, "{\"kind\":\"stage\",\"stage\":\"capture\",\"s\":0.").unwrap();
    {
        let sink = TelemetrySink::to_path(path.to_str().unwrap()).unwrap();
        sink.stage_s("accumulate", 1.0);
    }
    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 2, "torn tail terminated, new record on its own line: {text:?}");
    // the torn line stays torn (it carries no fabricated data), but it
    // can no longer corrupt the record appended after it
    let rec = Json::parse(lines[1]).unwrap();
    assert_eq!(rec.req("stage").unwrap().as_str(), Some("accumulate"));
    assert_eq!(rec.req("s").unwrap().as_f64(), Some(1.0));
    std::fs::remove_file(&path).ok();
}

#[test]
fn disabled_sink_is_a_no_op() {
    let sink = TelemetrySink::disabled();
    assert!(!sink.is_enabled());
    // none of these may panic or touch the filesystem
    sink.stage_s("capture", 1.0);
    sink.counter("batches_folded", 1);
    let _t = sink.start_timer("trainer_step");
}

/// The determinism contract end-to-end: telemetry observes, never
/// perturbs.  workers=1 and workers=4 produce bitwise-identical
/// factors, and their telemetry differs only in timings/identity.
#[test]
fn engine_smoke_is_bitwise_identical_across_workers_with_telemetry_on() {
    let ex = Executor::from_manifest(synthetic_manifest()).unwrap();
    let spec = ex.manifest.config("tiny").unwrap().clone();
    let w = synthetic_weights(&spec, 5);
    let src = SyntheticActivations::new(spec.clone(), 5);
    let comp = resolve("coala").unwrap();
    let mut job = CompressionJob::new("tiny", comp.method(), 0.4);
    job.calib_batches = 3;

    let mut ref_factors: Option<Vec<(String, Vec<f32>, Vec<f32>)>> = None;
    let mut ref_schema: Option<Vec<String>> = None;
    let mut ref_sweeps: Option<f64> = None;
    for workers in [1usize, 4] {
        let path = tmp_path(&format!("engine_w{workers}"));
        let mut plan = EnginePlan::with_workers(workers);
        plan.telemetry =
            TelemetrySink::to_path(path.to_str().unwrap()).unwrap().with_labels(|l| {
                l.config = "tiny".into();
                l.method = comp.name();
                l.route = "host".into();
                l.accum = "exact".into();
                l.workers = workers;
                l.shards = 1;
            });
        let pipe = Pipeline::new(&ex, spec.clone(), &w).with_route(Route::Host).with_plan(plan);
        let out = pipe.run_with_source(&job, &src).unwrap();
        assert!(out.model.all_finite());
        let factors: Vec<(String, Vec<f32>, Vec<f32>)> = out
            .model
            .factors
            .iter()
            .map(|(k, f)| (k.clone(), f.a.data.clone(), f.b.data.clone()))
            .collect();
        match &ref_factors {
            None => ref_factors = Some(factors),
            Some(fw) => assert_eq!(fw, &factors, "telemetry perturbed the engine at w={workers}"),
        }

        let recs = parsed_lines(&path);
        let stages: Vec<&str> = recs
            .iter()
            .filter(|r| r.req("kind").unwrap().as_str() == Some("stage"))
            .map(|r| r.req("stage").unwrap().as_str().unwrap())
            .collect();
        for want in ["capture", "accumulate", "merge_reduce", "factorize"] {
            assert!(stages.contains(&want), "w={workers}: stage `{want}` missing: {stages:?}");
        }
        assert!(
            recs.iter().any(|r| r.req("kind").unwrap().as_str() == Some("counter")
                && r.req("name").unwrap().as_str() == Some("projections_factorized")),
            "w={workers}: projections_factorized counter missing"
        );
        // the factorize stage reports its Jacobi convergence cost, and
        // the count — a sum of deterministic per-projection sweep
        // totals — is independent of the worker fan (this is the only
        // test in this binary that runs factorize, so the process-global
        // counter delta is not polluted by concurrent tests)
        let sweeps = recs
            .iter()
            .find(|r| r.req("kind").unwrap().as_str() == Some("counter")
                && r.req("name").unwrap().as_str() == Some("svd_sweeps"))
            .unwrap_or_else(|| panic!("w={workers}: svd_sweeps counter missing"))
            .req("value")
            .unwrap()
            .as_f64()
            .unwrap();
        assert!(sweeps >= 1.0, "w={workers}: factorize must run at least one Jacobi sweep");
        match ref_sweeps {
            None => ref_sweeps = Some(sweeps),
            Some(sw) => assert_eq!(sw, sweeps, "svd_sweeps differs at w={workers}"),
        }
        // schema fingerprint: everything except timing/identity fields
        // must be identical across worker counts
        let mut schema: Vec<String> = recs
            .iter()
            .map(|r| {
                let kind = r.req("kind").unwrap().as_str().unwrap().to_string();
                let what = r
                    .req("stage")
                    .or_else(|_| r.req("name"))
                    .unwrap()
                    .as_str()
                    .unwrap()
                    .to_string();
                let (config, method, route, accum) = (
                    r.req("config").unwrap().as_str().unwrap().to_string(),
                    r.req("method").unwrap().as_str().unwrap().to_string(),
                    r.req("route").unwrap().as_str().unwrap().to_string(),
                    r.req("accum").unwrap().as_str().unwrap().to_string(),
                );
                format!("{kind}/{what}/{config}/{method}/{route}/{accum}")
            })
            .collect();
        schema.sort();
        match &ref_schema {
            None => ref_schema = Some(schema),
            Some(sw) => assert_eq!(sw, &schema, "telemetry schema differs at w={workers}"),
        }
        std::fs::remove_file(&path).ok();
    }
}
